"""Attention layer correctness: chunked (online-softmax) == full, window and
softcap semantics, GQA broadcasting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import chunked_attention, full_attention


def _qkv(B=2, S=64, H=4, KV=2, D=16, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype) * 0.3
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), dtype) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), dtype) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    return q, k, v, pos


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_chunked_equals_full(window, softcap):
    q, k, v, pos = _qkv()
    a = full_attention(q, k, v, pos, pos, window=window, softcap=softcap)
    b = chunked_attention(q, k, v, pos, pos, window=window, softcap=softcap,
                          chunk=16)
    assert float(jnp.abs(a - b).max()) < 1e-5


def test_causality():
    """Changing future tokens must not change past outputs."""
    q, k, v, pos = _qkv(S=32)
    out1 = full_attention(q, k, v, pos, pos)
    k2 = k.at[:, 20:].set(9.0)
    v2 = v.at[:, 20:].set(-9.0)
    out2 = full_attention(q, k2, v2, pos, pos)
    assert float(jnp.abs(out1[:, :20] - out2[:, :20]).max()) < 1e-6
    assert float(jnp.abs(out1[:, 20:] - out2[:, 20:]).max()) > 1e-3


def test_window_limits_receptive_field():
    q, k, v, pos = _qkv(S=32)
    out_w = full_attention(q, k, v, pos, pos, window=4)
    # perturbing a key >4 positions in the past must not affect the output
    k2 = k.at[:, 0:2].set(7.0)
    out_w2 = full_attention(q, k2, v, pos, pos, window=4)
    assert float(jnp.abs(out_w[:, 8:] - out_w2[:, 8:]).max()) < 1e-6
