"""Crash-atomicity of super-bundle (v3/v4) in-place commits.

Covers: CRC-32C correctness (known vectors + reference implementation),
journal record parsing with torn tails, every crash phase of the
journaled in-place commit (after journal fsync / mid-slot / post-slots
pre-header / torn header / pre-commit-record), checksum-triggered drops
under ``verify="lazy"`` and ``verify="eager"``, v2 backward compatibility,
compaction of dead extents, ``LayerStore`` plumbing (``verify=``,
``dropped_entries``, ``maintain``), and the unified header-validation
error text.

The invariant under test: after ANY injected tear, reopening the
container succeeds, raw weights still serve byte-identically, and the
affected cache entry is either fully applied or fully rolled back —
``read_cached`` never returns torn bytes. Format-v4 quantized extents
(int8 / packed int4 + header scale metadata) get the same guarantees: a
torn quantized entry is dropped at open — never served — and recomputing
it from raw yields a bit-identical clean write.
"""
import struct

import numpy as np
import pytest

import repro.checkpoint.superbundle as S
from repro.checkpoint import LayerStore
from repro.checkpoint.bundle import _pad_to
from repro.checkpoint.integrity import crc32c
from repro.checkpoint.superbundle import (
    HEADER_SLACK, InjectedCrash, IntegrityError, SuperBundle, compact,
    drop_cache_entry, journal_path, read_super_header, recover_journal,
    set_cache_entries, set_cache_entry, write_superbundle,
)


# ---------------------------------------------------------------------------
# CRC-32C
# ---------------------------------------------------------------------------
def _crc_ref(data: bytes) -> int:
    """Textbook bytewise CRC-32C (reflected, poly 0x82F63B78)."""
    tab = []
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ 0x82F63B78 if c & 1 else c >> 1
        tab.append(c)
    crc = 0xFFFFFFFF
    for b in data:
        crc = (crc >> 8) ^ tab[(crc ^ b) & 0xFF]
    return crc ^ 0xFFFFFFFF


def test_crc32c_known_vectors():
    assert crc32c(b"") == 0
    assert crc32c(b"123456789") == 0xE3069283  # RFC 3720 check value
    assert crc32c(b"The quick brown fox jumps over the lazy dog") == 0x22620404


def test_crc32c_matches_reference_across_block_boundaries():
    rng = np.random.default_rng(0)
    # straddle the vectorized-block boundary (1024) and the bytewise tail
    for n in (1, 63, 1023, 1024, 1025, 2048, 5000):
        d = rng.integers(0, 256, n, dtype=np.uint8).tobytes()
        assert crc32c(d) == _crc_ref(d), n


def test_crc32c_incremental_and_ndarray():
    data = bytes(range(256)) * 20
    assert crc32c(data[100:], crc32c(data[:100])) == crc32c(data)
    a = np.arange(300, dtype=np.float32).reshape(30, 10)
    assert crc32c(a) == crc32c(a.tobytes())


def test_crc32c_fast_path_cross_checks_software():
    """Satellite (PR 5): when a C-backed CRC-32C is importable it serves
    the hot path, and it must agree bit-for-bit with the numpy software
    implementation on every size class and on incremental chaining."""
    from repro.checkpoint.integrity import _crc32c_software, crc32c_backend

    backend = crc32c_backend()
    rng = np.random.default_rng(7)
    for n in (0, 1, 63, 1023, 1024, 4096, 100_000):
        a = rng.integers(0, 256, n, dtype=np.uint8)
        assert crc32c(a) == _crc32c_software(a), (backend, n)
        mid = n // 2
        assert crc32c(a[mid:], crc32c(a[:mid])) == _crc32c_software(a), \
            (backend, n)
    # non-contiguous / non-uint8 arrays route through the same view logic
    f = rng.standard_normal((64, 8)).astype(np.float32)
    assert crc32c(f) == _crc32c_software(f)


def test_crc32c_software_env_override(monkeypatch):
    """REPRO_CRC32C=software must force the fallback (fleet debugging +
    the cross-check harness depend on it)."""
    import repro.checkpoint.integrity as integ

    monkeypatch.setenv("REPRO_CRC32C", "software")
    monkeypatch.setattr(integ, "_FAST", None)
    monkeypatch.setattr(integ, "_FAST_PROBED", False)
    assert integ.crc32c_backend() == "numpy-software"
    assert integ.crc32c(b"123456789") == 0xE3069283


# ---------------------------------------------------------------------------
# crash injection
# ---------------------------------------------------------------------------
def _model():
    return {"a": {"w": np.arange(200, dtype=np.float32)},
            "b": {"q": np.ones(30, np.int8)}}


OLD_CACHE = np.zeros(200, np.float32)
NEW_CACHE = np.full(200, 9.0, np.float32)


def _store(tmp_path, name):
    p = tmp_path / f"{name}.superbundle"
    write_superbundle(p, _model(), order=["a", "b"])
    set_cache_entry(p, "a", "kA", {"w": OLD_CACHE})  # append -> rewrite
    return p


def _crash_commit(p, phase, partial=False):
    """Replace the kA entry in place, crashing at ``phase``. ``partial``
    additionally tears the write itself (half a slot / garbled header)."""
    def hook(ph, **ctx):
        if ph != phase:
            return
        if partial and ph == "slot":
            f, off, payload = ctx["file"], ctx["offset"], ctx["payload"]
            f.seek(off)
            f.write(payload[: len(payload) // 2])
            f.flush()
        if partial and ph == "header":
            f, hdr = ctx["file"], ctx["header"]
            f.seek(0)
            f.write(b"NNVS" + struct.pack("<I", 3) + hdr[:40])
            f.flush()
        raise InjectedCrash(ph)

    S._crash_hook = hook
    try:
        with pytest.raises(InjectedCrash):
            set_cache_entry(p, "a", "kA", {"w": NEW_CACHE})
    finally:
        S._crash_hook = None


def _assert_recovered(p, expect):
    """Reopen with full verification: raw intact, cache entry fully old /
    fully new / dropped, journal drained, compaction leaves zero slack."""
    w = _model()
    with SuperBundle(p, verify="eager") as sb:
        for layer, tensors in w.items():
            got = sb.read_raw(layer, materialize=True)
            for k, v in tensors.items():
                np.testing.assert_array_equal(np.asarray(got[k]), v)
        if expect == "dropped":
            assert not sb.has_cached("a", "kA")
            assert any(d["layer"] == "a" and d["kernel"] == "kA"
                       for d in sb.dropped), sb.dropped
        else:
            assert not sb.dropped, sb.dropped
            want = OLD_CACHE if expect == "old" else NEW_CACHE
            got = np.asarray(sb.read_cached("a", "kA", materialize=True)["w"])
            np.testing.assert_array_equal(got, want)
    assert journal_path(p).stat().st_size == 0  # recovery drained the journal
    compact(p)
    with SuperBundle(p, verify="eager") as sb:
        assert sb.reclaimable_bytes() == 0


def test_crash_after_journal_before_data_keeps_old_entry(tmp_path):
    p = _store(tmp_path, "m")
    _crash_commit(p, "journal-synced")
    _assert_recovered(p, "old")


def test_crash_mid_slot_drops_torn_entry(tmp_path):
    p = _store(tmp_path, "m")
    _crash_commit(p, "slot", partial=True)
    _assert_recovered(p, "dropped")


def test_crash_post_slots_pre_header_rolls_forward(tmp_path):
    p = _store(tmp_path, "m")
    _crash_commit(p, "header")
    _assert_recovered(p, "new")


def test_crash_with_torn_header_restores_from_journal(tmp_path):
    p = _store(tmp_path, "m")
    _crash_commit(p, "header", partial=True)
    # the torn header must be detected before recovery even consults it
    with pytest.raises(ValueError):
        read_super_header(p)
    _assert_recovered(p, "new")


def test_crash_before_commit_record_rolls_forward(tmp_path):
    p = _store(tmp_path, "m")
    _crash_commit(p, "header-written")
    _assert_recovered(p, "new")


def test_torn_journal_tail_is_ignored(tmp_path):
    p = _store(tmp_path, "m")
    with open(journal_path(p), "ab") as f:
        f.write(b"SBJ1B" + struct.pack("<I", 9999) + b"torn")
    _assert_recovered(p, "old")


def test_truncated_journal_record_is_ignored(tmp_path):
    p = _store(tmp_path, "m")
    mid = np.full(200, 5.0, np.float32)
    assert set_cache_entry(p, "a", "kA", {"w": mid}) == "inplace"
    jb = journal_path(p).read_bytes()
    # tear off the COMMIT record's tail: the BEGIN still resolves (its data
    # fully landed) and rolls forward
    journal_path(p).write_bytes(jb[:-7])
    with SuperBundle(p, verify="eager") as sb:
        np.testing.assert_array_equal(
            np.asarray(sb.read_cached("a", "kA", materialize=True)["w"]), mid)
        assert not sb.dropped


def test_recover_journal_is_idempotent(tmp_path):
    p = _store(tmp_path, "m")
    _crash_commit(p, "slot", partial=True)
    first = recover_journal(p)
    assert len(first) == 1 and first[0]["layer"] == "a"
    assert recover_journal(p) == []  # drained: second replay is a no-op
    # the drop is already persisted in the header — later opens see a clean
    # container with no entry and nothing further to report
    with SuperBundle(p, verify="eager") as sb:
        assert not sb.has_cached("a", "kA")
        assert not sb.dropped
        np.testing.assert_array_equal(
            np.asarray(sb.read_raw("a")["w"]), _model()["a"]["w"])


def test_stale_journal_from_old_generation_is_ignored(tmp_path):
    p = _store(tmp_path, "m")
    _crash_commit(p, "journal-synced")  # pending record against gen G
    # a full rewrite supersedes the container (gen G+1) and resets the
    # journal; resurrect the stale record and check it is never replayed
    jb = journal_path(p).read_bytes()
    compact(p)
    journal_path(p).write_bytes(jb)
    _assert_recovered(p, "old")


# ---------------------------------------------------------------------------
# checksum verification without a journal (latent bit-rot)
# ---------------------------------------------------------------------------
def _flip_byte(p, offset):
    with open(p, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def test_eager_verify_drops_corrupt_cache_and_raises_on_raw(tmp_path):
    p = _store(tmp_path, "m")
    hdr = read_super_header(p)
    e = hdr["layers"]["a"]["cache"]["kA"][0]
    _flip_byte(p, e["offset"] + 5)
    with SuperBundle(p, verify="eager") as sb:
        assert not sb.has_cached("a", "kA")
        assert sb.dropped[0]["kernel"] == "kA"
        np.testing.assert_array_equal(
            np.asarray(sb.read_raw("a")["w"]), _model()["a"]["w"])

    p2 = _store(tmp_path, "m2")
    hdr = read_super_header(p2)
    _flip_byte(p2, hdr["layers"]["b"]["raw"][0]["offset"])
    with pytest.raises(IntegrityError, match="b/q"):
        SuperBundle(p2, verify="eager")


def test_lazy_verify_drops_on_first_materializing_read(tmp_path):
    p = _store(tmp_path, "m")
    hdr = read_super_header(p)
    e = hdr["layers"]["a"]["cache"]["kA"][0]
    _flip_byte(p, e["offset"] + 5)
    with SuperBundle(p, verify="lazy") as sb:
        assert sb.has_cached("a", "kA")  # not audited yet
        assert sb.read_cached("a", "kA", materialize=True) == {}
        assert not sb.has_cached("a", "kA")
        assert sb.dropped and sb.dropped[0]["kernel"] == "kA"
    with SuperBundle(p, verify="never") as sb:
        # never-mode serves bytes as-is — the caller opted out of auditing
        assert sb.read_cached("a", "kA", materialize=True)["w"].shape == (200,)
    # compaction re-audits and refuses to carry the corrupt entry forward
    stats = compact(p)
    assert stats["dropped"] and stats["dropped"][0]["kernel"] == "kA"


def test_invalid_verify_mode_rejected(tmp_path):
    p = _store(tmp_path, "m")
    with pytest.raises(ValueError, match="never|lazy|eager"):
        SuperBundle(p, verify="sometimes")


# ---------------------------------------------------------------------------
# v2 backward compatibility
# ---------------------------------------------------------------------------
def _write_v2(path, name, arr):
    """Hand-rolled minimal v2 container (no checksums, no generation)."""
    arr = np.ascontiguousarray(arr)
    entry = {"name": name, "dtype": str(arr.dtype), "shape": list(arr.shape),
             "nbytes": int(arr.nbytes)}
    header = {"order": ["l"], "layers": {"l": {"raw": [entry], "cache": {}}}}
    import json
    for _ in range(8):
        hdr = json.dumps(header, separators=(",", ":")).encode()
        off = _pad_to(16 + len(hdr) + HEADER_SLACK)
        if entry.get("offset") == off:
            break
        entry["offset"] = off
    with open(path, "wb") as f:
        f.write(struct.pack("<4sIQ", b"NNVS", 2, len(hdr)))
        f.write(hdr)
        f.write(b"\0" * (off - f.tell()))
        f.write(arr.tobytes())


def test_v2_container_reads_and_upgrades_to_current(tmp_path):
    p = tmp_path / "old.superbundle"
    arr = np.arange(40, dtype=np.float32)
    _write_v2(p, "w", arr)
    with SuperBundle(p, verify="eager") as sb:  # no checksums: nothing fails
        assert sb.version == 2 and sb.generation == 0
        np.testing.assert_array_equal(
            np.asarray(sb.read_raw("l", materialize=True)["w"]), arr)
    # any mutation upgrades via the rewrite path (v2 has no slot checksums,
    # so the journaled in-place commit refuses to run on it)
    assert set_cache_entry(p, "l", "k", {"w": arr}) == "rewrite"
    with SuperBundle(p, verify="eager") as sb:
        assert sb.version == S.VERSION and sb.generation == 1
        assert all("crc32c" in e for e in sb._all_entries("l"))


def test_version_too_new_error_is_consistent(tmp_path):
    p = _store(tmp_path, "m")
    _flip = struct.pack("<I", 99)
    with open(p, "r+b") as f:
        f.seek(4)
        f.write(_flip)
    with pytest.raises(ValueError) as e1:
        read_super_header(p)
    with pytest.raises(ValueError) as e2:
        SuperBundle(p, recover=False)
    # ONE shared validator: identical message, naming the file and both
    # the found and the supported version
    assert str(e1.value) == str(e2.value)
    assert str(p) in str(e1.value)
    assert "99" in str(e1.value) and str(S.VERSION) in str(e1.value)


# ---------------------------------------------------------------------------
# LayerStore plumbing + engine hook
# ---------------------------------------------------------------------------
def test_layerstore_surfaces_dropped_entries_and_raw_survives(tmp_path):
    st = LayerStore(tmp_path, fmt="super")
    for layer, tensors in _model().items():
        st.write_raw(layer, tensors)
    st.write_cached("a", "kA", {"w": OLD_CACHE})
    assert st.cache_bytes() > 0  # flush
    p = tmp_path / "model.superbundle"
    _crash_commit(p, "slot", partial=True)
    st2 = LayerStore(tmp_path, fmt="super")
    np.testing.assert_array_equal(
        np.asarray(st2.read_raw("a", mmap=False)["w"]), _model()["a"]["w"])
    assert any(d["kernel"] == "kA" for d in st2.dropped_entries)
    assert not st2.has_cached("a", "kA")
    # maintain() compacts the dead extent the rolled-back entry left
    stats = st2.maintain()
    assert stats["compacted"] and stats["reclaimed_bytes"] > 0


def test_layerstore_maintain_background(tmp_path):
    st = LayerStore(tmp_path, fmt="super")
    st.write_raw("l", {"w": np.ones(4096, np.float32)})
    st.write_cached("l", "k", {"w": np.ones(4096, np.float32)})
    assert st.cache_bytes() > 0  # flush
    st.drop_cached("l", "k")
    stats = st.maintain(background=True)
    assert stats["compacted"] and stats.get("background")
    real = st.maintain_wait()
    assert real is not None and real["reclaimed_bytes"] > 0
    assert st.maintain_wait() is None  # nothing pending anymore
    with SuperBundle(tmp_path / "model.superbundle") as sb:
        assert sb.reclaimable_bytes() == 0


def test_rewrite_over_existing_container_derives_fresh_generation(tmp_path):
    """A default-generation rewrite (e.g. ``migrate`` onto an existing
    path) must still supersede the old container's generation, so stale
    journal records can never replay against the new file."""
    p = tmp_path / "m.superbundle"
    write_superbundle(p, _model(), order=["a", "b"])
    assert read_super_header(p)["generation"] == 0
    write_superbundle(p, _model(), order=["a", "b"])  # default generation
    assert read_super_header(p)["generation"] == 1
    # and past any pending journal record, even with the header torn
    _crash_commit(_store(tmp_path, "m2"), "journal-synced")
    p2 = tmp_path / "m2.superbundle"
    gen_rec = 1 + int(read_super_header(p2)["generation"])
    with open(p2, "r+b") as f:
        f.write(b"XXXX")  # torn magic: old header unreadable
    write_superbundle(p2, _model(), order=["a", "b"])
    assert read_super_header(p2)["generation"] >= gen_rec


def test_layerstore_harvests_lazy_drops_after_open(tmp_path):
    st = LayerStore(tmp_path, fmt="super")
    for layer, tensors in _model().items():
        st.write_raw(layer, tensors)
    st.write_cached("a", "kA", {"w": OLD_CACHE})
    assert st.cache_bytes() > 0  # flush
    hdr = read_super_header(tmp_path / "model.superbundle")
    _flip_byte(tmp_path / "model.superbundle",
               hdr["layers"]["a"]["cache"]["kA"][0]["offset"] + 3)
    st2 = LayerStore(tmp_path, fmt="super")
    assert st2.read_cached("a", "kA", mmap=False) == {}  # lazy audit drops
    st2.close()  # reader invalidation harvests the post-open drop report
    assert any(d["kernel"] == "kA" for d in st2.dropped_entries)


def test_rewrite_audits_extents_instead_of_restamping(tmp_path):
    """A container rewrite restamps fresh checksums — it must audit the
    bytes it copies forward, or latent bit-rot would be laundered into
    'verified' data. Corrupt cache entries are dropped; corrupt raw
    refuses to rewrite."""
    st = LayerStore(tmp_path, fmt="super")
    for layer, tensors in _model().items():
        st.write_raw(layer, tensors)
    st.write_cached("a", "kA", {"w": OLD_CACHE})
    assert st.cache_bytes() > 0  # flush
    p = tmp_path / "model.superbundle"
    hdr = read_super_header(p)
    _flip_byte(p, hdr["layers"]["a"]["cache"]["kA"][0]["offset"] + 7)
    st2 = LayerStore(tmp_path, fmt="super")
    st2.write_raw("c", {"z": np.ones(8, np.float32)})
    st2.read_raw("c")  # flush -> full rewrite
    with SuperBundle(p, verify="eager") as sb:
        assert not sb.has_cached("a", "kA")  # dropped, not restamped
    assert any(d["kernel"] == "kA" for d in st2.dropped_entries)

    st3 = LayerStore(tmp_path / "rawrot", fmt="super")
    st3.write_raw("a", _model()["a"])
    st3.read_raw("a")  # flush
    p3 = tmp_path / "rawrot" / "model.superbundle"
    _flip_byte(p3, read_super_header(p3)["layers"]["a"]["raw"][0]["offset"])
    st4 = LayerStore(tmp_path / "rawrot", fmt="super")
    st4.write_raw("b", {"q": np.ones(4, np.float32)})
    with pytest.raises(IntegrityError):
        st4.read_raw("b")  # flush must refuse to copy rotten raw forward


def test_pipeline_prep_falls_back_when_cache_dropped(tmp_path):
    """A use_cache layer whose entry was dropped (recovery/audit) must be
    re-derived from raw by the runtime, never executed with no weights."""
    import threading
    import time as time_mod

    from repro.core.pipeline import PipelineRuntime
    from repro.core.registry import LayerSpec

    st = LayerStore(tmp_path, fmt="super")
    raw = {"w": np.arange(8, dtype=np.float32)}
    st.write_raw("l", raw)
    st.read_raw("l")  # flush; NO cache entry exists for kernel "k"

    class Kern:
        name = "k"

        def transform(self, w, spec):
            return {"w": np.asarray(w["w"]) * 2}

    spec = LayerSpec(name="l", op_type="linear",
                     weight_shapes={"w": (8,)})
    rt = PipelineRuntime([spec], {"l": Kern()}, {"l": True}, st,
                         {"l": lambda w, x: x}, n_little=1)
    weights, traces = {}, []
    rt._prepare("l", weights, traces, "little", time_mod.perf_counter(),
                threading.Lock())
    np.testing.assert_array_equal(np.asarray(weights["l"]["w"]),
                                  raw["w"] * 2)


def test_maintain_quiesces_before_new_writes(tmp_path):
    """A mutation (or second maintain) while a background compaction is in
    flight must join it first — two concurrent rewrites would interleave
    into the same tmp file."""
    st = LayerStore(tmp_path, fmt="super")
    st.write_raw("l", {"w": np.ones(4096, np.float32)})
    st.write_cached("l", "k", {"w": np.ones(4096, np.float32)})
    assert st.cache_bytes() > 0  # flush
    st.drop_cached("l", "k")
    assert st.maintain(background=True)["compacted"]
    st.write_cached("l", "k2", {"w": np.zeros(16, np.float32)})  # quiesces
    assert st._maintain_thread is None  # background run was joined
    assert st.cache_bytes() > 0  # flush merges cleanly on top
    with SuperBundle(tmp_path / "model.superbundle", verify="eager") as sb:
        assert sb.has_cached("l", "k2") and not sb.dropped


def test_engine_decide_reports_store_maintenance(tmp_path):
    from repro.core.engine import ColdEngine
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    eng = ColdEngine(layers, tmp_path / "s", store_fmt="super")
    stats = eng.decide(x, n_little=2)
    assert "store_maintenance" in stats
    with SuperBundle(tmp_path / "s" / "model.superbundle") as sb:
        # decide()'s drops/materializations end fully compacted
        assert sb.reclaimable_bytes() == 0
    out = np.asarray(eng.run_cold(x).output)
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# batched multi-entry transactions (PR 6, journal batching)
# ---------------------------------------------------------------------------
OLD_B_CACHE = np.zeros(30, np.int8)
NEW_B_CACHE = np.full(30, 7, np.int8)


def _batch_store(tmp_path):
    p = tmp_path / "batch.superbundle"
    write_superbundle(p, _model(), order=["a", "b"])
    set_cache_entry(p, "a", "kA", {"w": OLD_CACHE})
    set_cache_entry(p, "b", "kB", {"q": OLD_B_CACHE})
    return p


def _crash_batch(p, phase, partial=False):
    """Replace BOTH entries in one transaction, crashing at ``phase``.
    ``partial`` tears the first slot write (entry a) mid-payload."""
    def hook(ph, **ctx):
        if ph != phase:
            return
        if partial and ph == "slot":
            f, off, payload = ctx["file"], ctx["offset"], ctx["payload"]
            f.seek(off)
            f.write(payload[: len(payload) // 2])
            f.flush()
        raise InjectedCrash(ph)

    S._crash_hook = hook
    try:
        with pytest.raises(InjectedCrash):
            set_cache_entries(p, {("a", "kA"): {"w": NEW_CACHE},
                                  ("b", "kB"): {"q": NEW_B_CACHE}})
    finally:
        S._crash_hook = None


def _assert_batch(p, expect_a, expect_b):
    """Per-entry resolution: each entry of the torn batch independently
    ends fully old, fully new, or dropped — never torn."""
    with SuperBundle(p, verify="eager") as sb:
        for layer, tensors in _model().items():
            got = sb.read_raw(layer, materialize=True)
            for k, v in tensors.items():
                np.testing.assert_array_equal(np.asarray(got[k]), v)
        for layer, kernel, tname, old, new, expect in (
                ("a", "kA", "w", OLD_CACHE, NEW_CACHE, expect_a),
                ("b", "kB", "q", OLD_B_CACHE, NEW_B_CACHE, expect_b)):
            if expect == "dropped":
                assert not sb.has_cached(layer, kernel)
                assert any(d["layer"] == layer and d["kernel"] == kernel
                           for d in sb.dropped), sb.dropped
            else:
                want = old if expect == "old" else new
                got = np.asarray(sb.read_cached(
                    layer, kernel, materialize=True)[tname])
                np.testing.assert_array_equal(got, want)
    assert journal_path(p).stat().st_size == 0  # recovery drained it
    compact(p)
    with SuperBundle(p, verify="eager") as sb:
        assert sb.reclaimable_bytes() == 0


def test_batched_crash_after_journal_keeps_both_old(tmp_path):
    p = _batch_store(tmp_path)
    _crash_batch(p, "journal-synced")
    _assert_batch(p, "old", "old")


def test_batched_crash_mid_slot_drops_only_the_torn_entry(tmp_path):
    # entry a's slot is half-written; entry b's bytes were never touched —
    # per-entry resolution must drop a and keep b fully old
    p = _batch_store(tmp_path)
    _crash_batch(p, "slot", partial=True)
    _assert_batch(p, "dropped", "old")


@pytest.mark.parametrize("phase", ["header", "header-written"])
def test_batched_crash_post_slots_rolls_both_forward(tmp_path, phase):
    p = _batch_store(tmp_path)
    _crash_batch(p, phase)
    _assert_batch(p, "new", "new")


def test_batched_refresh_is_one_fsync_pair(tmp_path, monkeypatch):
    """N replacements in one transaction cost ONE journal fsync + ONE
    container fsync; the unbatched path pays a pair per entry."""
    p = _batch_store(tmp_path)
    calls = []
    real = S.fsync_file
    monkeypatch.setattr(S, "fsync_file",
                        lambda f: (calls.append(1), real(f))[1])
    res = set_cache_entries(p, {("a", "kA"): {"w": NEW_CACHE},
                                ("b", "kB"): {"q": NEW_B_CACHE}})
    assert res["mode"] == "inplace"
    assert len(calls) == 2
    _assert_batch(p, "new", "new")
    calls.clear()
    set_cache_entry(p, "a", "kA", {"w": OLD_CACHE})
    set_cache_entry(p, "b", "kB", {"q": OLD_B_CACHE})
    # a pair PER entry (plus journal drains on reopen): strictly worse
    assert len(calls) >= 4


def test_layerstore_flush_batches_cache_refreshes(tmp_path, monkeypatch):
    """The store buffers write_cached() calls; a flush over N existing
    same-shape entries commits them as ONE journaled transaction."""
    st = LayerStore(tmp_path, fmt="super")
    w = {f"l{i}": {"w": np.arange(64, dtype=np.float32) + i}
         for i in range(3)}
    for layer, tensors in w.items():
        st.write_raw(layer, tensors)
        st.write_cached(layer, "k", {"w": tensors["w"] * 2})
    st._super(flush_all=True)  # initial materialization: one rewrite
    calls = []
    real = S.fsync_file
    monkeypatch.setattr(S, "fsync_file",
                        lambda f: (calls.append(1), real(f))[1])
    for layer, tensors in w.items():
        st.write_cached(layer, "k", {"w": tensors["w"] * 3})
    st._super(flush_all=True)  # 3 replacements -> ONE in-place txn
    # one fsync pair for the whole commit + ONE deferred journal drain
    # when the shared reader reopens — constant in N (per-entry commits
    # would cost a pair each, >= 6 here)
    assert len(calls) == 3
    for layer, tensors in w.items():
        np.testing.assert_array_equal(
            np.asarray(st.read_cached(layer, "k", mmap=False)["w"]),
            tensors["w"] * 3)


# ---------------------------------------------------------------------------
# crashes during compaction / background maintenance (PR 6)
# ---------------------------------------------------------------------------
def test_crash_during_compact_preserves_original(tmp_path, monkeypatch):
    """compact() publishes by atomic rename: a crash anywhere before the
    rename leaves the original container untouched and a retry heals."""
    p = _store(tmp_path, "m")
    drop_cache_entry(p, "a", "kA")  # dead extent -> compactable slack

    def crash_write(path, emit, durable=True):
        raise InjectedCrash("compact-rewrite")

    monkeypatch.setattr(S, "atomic_write", crash_write)
    with pytest.raises(InjectedCrash):
        compact(p)
    monkeypatch.undo()
    with SuperBundle(p, verify="eager") as sb:
        np.testing.assert_array_equal(
            np.asarray(sb.read_raw("a", materialize=True)["w"]),
            _model()["a"]["w"])
        assert sb.reclaimable_bytes() > 0  # slack still there, file intact
    stats = compact(p)  # retry succeeds
    assert stats["reclaimed_bytes"] > 0
    with SuperBundle(p, verify="eager") as sb:
        assert sb.reclaimable_bytes() == 0


def test_background_maintain_crash_surfaces_and_store_survives(
        tmp_path, monkeypatch):
    """A compaction failing in the background thread must be re-raised by
    maintain_wait(), never swallowed — and the container it was rewriting
    stays fully serveable."""
    st = LayerStore(tmp_path, fmt="super")
    st.write_raw("l", {"w": np.ones(4096, np.float32)})
    st.write_cached("l", "k", {"w": np.ones(4096, np.float32)})
    assert st.cache_bytes() > 0  # flush
    st.drop_cached("l", "k")  # in-place drop leaves a dead extent

    def crash_write(path, emit, durable=True):
        raise InjectedCrash("bg-compact")

    monkeypatch.setattr(S, "atomic_write", crash_write)
    assert st.maintain(background=True)["compacted"]
    with pytest.raises(InjectedCrash):
        st.maintain_wait()
    monkeypatch.undo()
    np.testing.assert_array_equal(
        np.asarray(st.read_raw("l", mmap=False)["w"]),
        np.ones(4096, np.float32))
    real = st.maintain()  # retry on the intact container heals
    assert real["compacted"] and real["reclaimed_bytes"] > 0


# ---------------------------------------------------------------------------
# quantized cache extents (format v4) under crashes
# ---------------------------------------------------------------------------
def _quant_model():
    rng = np.random.default_rng(21)
    return {"a": {"w": rng.standard_normal((40, 12)).astype(np.float32)},
            "b": {"q": np.ones(30, np.int8)}}


def _quant_entries(weights, seed):
    """Deterministic int4 companions for layer a's weight. seed != 0 adds
    an additive perturbation so old/new PAYLOAD bytes differ (a pure
    rescale would quantize to identical int values and recovery would
    rightly roll forward) while folded shapes stay identical."""
    from repro import quant

    w = weights["a"]["w"]
    if seed:
        rng = np.random.default_rng(100 + seed)
        w = w + rng.standard_normal(w.shape).astype(np.float32)
    return quant.quantize_weight("w", np.asarray(w, np.float32), bits=4)


def _quant_store(tmp_path, name):
    p = tmp_path / f"{name}.superbundle"
    write_superbundle(p, _quant_model(), order=["a", "b"])
    set_cache_entry(p, "a", "int4", _quant_entries(_quant_model(), 0))
    return p


def _crash_quant_commit(p, phase, partial=False):
    def hook(ph, **ctx):
        if ph != phase:
            return
        if partial and ph == "slot":
            f, off, payload = ctx["file"], ctx["offset"], ctx["payload"]
            f.seek(off)
            f.write(payload[: len(payload) // 2])
            f.flush()
        raise InjectedCrash(ph)

    S._crash_hook = hook
    try:
        with pytest.raises(InjectedCrash):
            set_cache_entry(p, "a", "int4",
                            _quant_entries(_quant_model(), 1))
    finally:
        S._crash_hook = None


def _assert_quant_recovered(p, expect):
    w = _quant_model()
    want = _quant_entries(w, 0 if expect == "old" else 1)
    with SuperBundle(p, verify="eager") as sb:
        np.testing.assert_array_equal(
            np.asarray(sb.read_raw("a", materialize=True)["w"]), w["a"]["w"])
        if expect == "dropped":
            # torn int4 extent: dropped at open, NEVER served
            assert not sb.has_cached("a", "int4")
            assert sb.read_cached("a", "int4", materialize=True) == {}
            assert any(d["layer"] == "a" and d["kernel"] == "int4"
                       for d in sb.dropped), sb.dropped
        else:
            got = sb.read_cached("a", "int4", materialize=True)
            assert set(got) == set(want)
            for k in want:
                assert got[k].dtype == want[k].dtype, k
                np.testing.assert_array_equal(np.asarray(got[k]), want[k])
    assert journal_path(p).stat().st_size == 0


@pytest.mark.parametrize("phase,partial,expect", [
    ("journal-synced", False, "old"),
    ("slot", True, "dropped"),
    ("header", False, "new"),
    ("header-written", False, "new"),
])
def test_quantized_extent_crash_phases(tmp_path, phase, partial, expect):
    p = _quant_store(tmp_path, "q")
    _crash_quant_commit(p, phase, partial=partial)
    _assert_quant_recovered(p, expect)


def test_torn_int4_entry_recomputes_from_raw_bit_identical(tmp_path):
    """The degradation ladder's recompute-from-raw: after a torn int4
    extent is dropped, re-running the transform on the (intact) raw bytes
    and committing must produce a container byte-identical in content to
    one that never crashed."""
    p = _quant_store(tmp_path, "q")
    _crash_quant_commit(p, "slot", partial=True)
    _assert_quant_recovered(p, "dropped")
    # ladder recompute: transform(raw) -> write. Quantization is
    # deterministic, so this equals a clean write of the same entry.
    with SuperBundle(p) as sb:
        raw = {k: np.asarray(v, np.float32)
               for k, v in sb.read_raw("a", materialize=True).items()}
    recomputed = _quant_entries({"a": raw}, 1)
    set_cache_entry(p, "a", "int4", recomputed)
    clean = tmp_path / "clean.superbundle"
    write_superbundle(clean, _quant_model(), order=["a", "b"])
    set_cache_entry(clean, "a", "int4", _quant_entries(_quant_model(), 1))
    with SuperBundle(p, verify="eager") as sb, \
            SuperBundle(clean, verify="eager") as sc:
        a = sb.read_cached("a", "int4", materialize=True)
        b = sc.read_cached("a", "int4", materialize=True)
        assert set(a) == set(b) == {"w:q4", "w:qscale"}
        for k in a:
            assert a[k].dtype == b[k].dtype
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
        assert not sb.dropped


def test_pipeline_prep_rederives_quantized_entry_after_drop(tmp_path):
    """Runtime rung of the same ladder: a use_cache layer whose quantized
    entry was dropped must be re-derived from raw by the pipeline runtime
    — bit-identical companions, never empty weights."""
    import threading
    import time as time_mod

    from repro import quant
    from repro.core.pipeline import PipelineRuntime
    from repro.core.registry import LayerSpec, LinearInt4

    st = LayerStore(tmp_path, fmt="super")
    raw = _quant_model()["a"]
    st.write_raw("l", raw)
    st.read_raw("l")  # flush; NO cache entry exists for kernel "int4"

    kern = LinearInt4()
    spec = LayerSpec(name="l", op_type="linear",
                     weight_shapes={"w": raw["w"].shape})
    rt = PipelineRuntime([spec], {"l": kern}, {"l": True}, st,
                         {"l": lambda w, x: x}, n_little=1)
    weights, traces = {}, []
    rt._prepare("l", weights, traces, "little", time_mod.perf_counter(),
                threading.Lock())
    want = kern.transform(dict(raw), spec)
    assert set(weights["l"]) == set(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(weights["l"][k]),
                                      np.asarray(want[k]))
    assert quant.is_quantized(
        {k: np.asarray(v) for k, v in weights["l"].items()})


def test_readers_race_crashing_compaction_see_only_committed_state(tmp_path):
    """Independent readers hammering the container while a background
    compaction crashes (and then a retry succeeds) must only ever observe
    fully committed state — old or new generation, never torn bytes."""
    import threading

    st = LayerStore(tmp_path, fmt="super")
    raw = {"w": np.arange(4096, dtype=np.float32)}
    st.write_raw("l", raw)
    st.write_cached("l", "k", {"w": raw["w"] * 2})
    st.write_cached("l", "dead", {"w": raw["w"] * 3})
    assert st.cache_bytes() > 0  # flush
    st.drop_cached("l", "dead")  # slack for the compaction to reclaim

    p = tmp_path / "model.superbundle"
    stop = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            try:
                with SuperBundle(p, verify="eager") as sb:
                    got = np.asarray(
                        sb.read_raw("l", materialize=True)["w"])
                    if not np.array_equal(got, raw["w"]):
                        errors.append("torn raw bytes")
                    c = sb.read_cached("l", "k", materialize=True)
                    if c and not np.array_equal(np.asarray(c["w"]),
                                                raw["w"] * 2):
                        errors.append("torn cache bytes")
            except Exception as e:  # noqa: BLE001 - collected for assert
                errors.append(repr(e))

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        real_write = S.atomic_write

        def crash_write(path, emit, durable=True):
            raise InjectedCrash("bg-compact")

        S.atomic_write = crash_write
        try:
            st.maintain(background=True)
            with pytest.raises(InjectedCrash):
                st.maintain_wait()
        finally:
            S.atomic_write = real_write
        stats = st.maintain(background=True)  # retry, racing the readers
        assert stats["compacted"]
        assert st.maintain_wait()["reclaimed_bytes"] > 0
    finally:
        stop.set()
        for t in threads:
            t.join(10)
    assert errors == []
    with SuperBundle(p, verify="eager") as sb:
        assert sb.reclaimable_bytes() == 0
