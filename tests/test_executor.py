"""Executor subsystem: task graphs, the persistent CorePool, ColdServer.

Covers the PR-5 invariants: plan ↔ task-graph equivalence (one shared
representation), zero per-run thread creation on the steady path, work
stealing under a persistent pool across back-to-back runs, deferred-staging
traces landing before results, and two models cold-starting concurrently
without cross-talk in traces or weights.
"""
import time

import numpy as np
import pytest

from repro.core.scheduler import (
    Choice, LayerCandidates, Plan, pick_steal_donor, schedule, simulate,
)
from repro.executor.graph import TaskGraph, compile_plan, simulate_graph
from repro.executor.pool import CorePool, get_core_pool


# ---------------------------------------------------------------------------
# graph ↔ plan equivalence
# ---------------------------------------------------------------------------
def _random_cands(n, rng, kernels=("a", "b")):
    cands = []
    for i in range(n):
        opts = []
        for k in kernels:
            pl, pb, ex = rng.uniform(0.5, 3.0, 3)
            opts.append((Choice(k, False), float(pl), float(pb), float(ex)))
        cands.append(LayerCandidates(layer=f"l{i}", options=opts))
    return cands


def test_compiled_graph_simulates_identically_to_plan():
    """compile_plan must preserve exactly the structure the scheduler's
    simulator models: big preps, lane queues, exec order."""
    rng = np.random.default_rng(0)
    for trial in range(5):
        cands = _random_cands(8, rng)
        plan = schedule(cands, M_l=2)
        order = [c.layer for c in cands]
        chosen = [next(o for o in c.options if o[0] == ch)
                  for c, ch in zip(cands, plan.choices)]
        pl = [o[1] for o in chosen]
        pb = [o[2] for o in chosen]
        ex = [o[3] for o in chosen]
        graph = compile_plan(order, plan,
                             weighted={n: True for n in order},
                             use_cache={n: False for n in order})
        mk_plan, bd_plan = simulate(pl, pb, ex, plan.big_prep,
                                    plan.little_queues)
        mk_graph, bd_graph = simulate_graph(graph, order, pl, pb, ex)
        assert mk_graph == pytest.approx(mk_plan, abs=1e-12)
        assert bd_graph == bd_plan
        # structure recovery round-trips
        idx = {n: i for i, n in enumerate(order)}
        assert [idx[n] for n in graph.big_prep_layers()] == plan.big_prep
        queues = graph.lane_queues()
        assert [[idx[n] for n in queues.get(j, [])]
                for j in range(len(plan.little_queues))] == \
            [list(q) for q in plan.little_queues]


def test_graph_typed_tasks_and_deps():
    plan = Plan(choices=[Choice("k", False), Choice("k", True)],
                big_prep=[0], little_queues=[[1]], est_makespan=0.0)
    g = compile_plan(["x", "y"], plan,
                     weighted={"x": True, "y": True},
                     use_cache={"x": False, "y": True})
    # raw chain: read -> transform -> stage; cached chain skips transform
    assert [t.kind for t in g.tasks if t.layer == "x" and t.kind != "execute"] \
        == ["read", "transform", "stage"]
    assert [t.kind for t in g.tasks if t.layer == "y" and t.kind != "execute"] \
        == ["read", "stage"]
    ex_x = g.task("x", "execute")
    ex_y = g.task("y", "execute")
    assert g.task("x", "stage").tid in ex_x.deps
    assert ex_x.tid in ex_y.deps and g.task("y", "stage").tid in ex_y.deps
    assert g.task("x", "read").affinity == "big"
    assert g.task("y", "read").affinity == "little" \
        and g.task("y", "read").lane == 0
    g.validate()


def test_pick_steal_donor_rule():
    remaining = {0: ["a", "b"], 1: ["c"], 2: []}
    costs = {"a": 1.0, "b": 1.0, "c": 5.0}
    assert pick_steal_donor(remaining, costs.get) == 1
    assert pick_steal_donor({0: [], 1: []}, costs.get) is None


# ---------------------------------------------------------------------------
# pool semantics (synthetic graphs — no engine, fast)
# ---------------------------------------------------------------------------
@pytest.fixture()
def pool():
    p = CorePool(n_big=1, n_little=2, name="test")
    yield p
    p.shutdown()


def _prep_graph(layers_per_lane, *, cost=lambda n: 1.0, sleep=0.01,
                log=None):
    """A prep-only graph: one read task per layer, per-lane queues."""
    g = TaskGraph()
    for lane, layers in enumerate(layers_per_lane):
        for name in layers:
            def fn(name=name):
                time.sleep(sleep)
                if log is not None:
                    log.append(name)
            g.add(name, "read", affinity="little", lane=lane,
                  cost=cost(name), fn=fn)
    return g

def test_work_stealing_under_persistent_pool_two_runs(pool):
    """An idle little worker must steal the TAIL of the most loaded lane,
    run after run, on the same pool threads."""
    for run in range(2):
        log = []
        g = _prep_graph([["a1", "a2", "a3", "a4"], ["b1"]], log=log,
                        sleep=0.02)
        steals0 = pool.steals
        job = pool.submit(g, name=f"run{run}")
        job.wait(10)
        assert pool.steals > steals0, "no steal happened"
        # the thief (lane-1 worker, done after b1) took a tail 'a' layer
        a_cores = {t.layer: t.core for t in job.traces
                   if t.layer.startswith("a")}
        assert "little1" in a_cores.values(), a_cores
        assert len(job.traces) == 5
    assert pool.threads_created == 3  # 1 big + 2 little, created once


def test_no_thread_creation_on_steady_path(pool):
    g1 = _prep_graph([["a"], ["b"]])
    pool.submit(g1, name="warmup").wait(10)
    before = pool.threads_created
    for _ in range(3):
        g = _prep_graph([["a"], ["b"]])
        pool.submit(g, name="steady").wait(10)
    assert pool.threads_created == before


def test_per_job_trace_accounting(pool):
    """Two jobs in flight: each job's traces contain exactly its own tasks,
    timed against its own clock."""
    g1 = _prep_graph([["x1", "x2"]], sleep=0.02)
    g2 = _prep_graph([[], ["y1", "y2"]], sleep=0.02)
    j1 = pool.submit(g1, name="j1", allow_steal=False)
    j2 = pool.submit(g2, name="j2", allow_steal=False)
    j1.wait(10), j2.wait(10)
    assert {t.layer for t in j1.traces} == {"x1", "x2"}
    assert {t.layer for t in j2.traces} == {"y1", "y2"}
    for j in (j1, j2):
        assert all(t.end >= t.start >= 0.0 for t in j.traces)


def test_failing_task_cancels_job_not_pool(pool):
    g = TaskGraph()
    g.add("l", "read", affinity="little", lane=0,
          fn=lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    g.add("l", "stage", affinity="any", deps=(0,), fn=lambda: None)
    job = pool.submit(g, name="bad")
    with pytest.raises(RuntimeError, match="boom"):
        job.wait(10)
    # pool still serves subsequent jobs
    ok = pool.submit(_prep_graph([["z"]]), name="after")
    ok.wait(10)
    assert {t.layer for t in ok.traces} == {"z"}


def test_preps_done_callback_fires_on_failure_and_late_registration(pool):
    """Admission slots must never leak: preps-done fires even when a prep
    task fails, and a callback registered after the prep phase ended runs
    immediately."""
    g = TaskGraph()
    g.add("l", "read", affinity="little", lane=0,
          fn=lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    fired = []
    job = pool.submit(g, name="failing")
    job.add_preps_callback(lambda j: fired.append("fail"))
    with pytest.raises(RuntimeError):
        job.wait(10)
    deadline = time.time() + 2.0
    while len(fired) < 1 and time.time() < deadline:
        time.sleep(0.005)
    assert fired == ["fail"]
    # late registration on a finished job
    ok = pool.submit(_prep_graph([["z"]]), name="late")
    ok.wait(10)
    ok.add_preps_callback(lambda j: fired.append("late"))
    assert fired == ["fail", "late"]
    # prep-free jobs count as preps-done from the start
    g3 = TaskGraph()
    g3.add("l", "execute", affinity="big", fn=lambda: None)
    j3 = pool.submit(g3, name="prepfree")
    j3.add_preps_callback(lambda j: fired.append("prepfree"))
    assert fired[-1] == "prepfree"
    j3.wait(10)


def test_empty_and_unbound_graphs(pool):
    job = pool.submit(TaskGraph(), name="empty")
    job.wait(1)
    g = TaskGraph()
    g.add("l", "read", affinity="big")       # fn never bound
    with pytest.raises(ValueError, match="no bound fn"):
        pool.submit(g)


# ---------------------------------------------------------------------------
# engine-level: steady path + deferred staging through the real pipeline
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_engine(tmp_path_factory):
    from repro.core.engine import ColdEngine
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    eng = ColdEngine(layers, tmp_path_factory.mktemp("exec_store"))
    eng.decide(x, n_little=2)
    return eng, x


def test_cold_runs_create_no_threads_after_warmup(tiny_engine):
    eng, x = tiny_engine
    eng.run_cold(x, n_little=2)          # warm-up may grow the pool
    pool = get_core_pool()
    before = pool.threads_created
    r1 = eng.run_cold(x, n_little=2)
    r2 = eng.run_cold(x, n_little=2)
    assert pool.threads_created == before
    np.testing.assert_array_equal(np.asarray(r1.output),
                                  np.asarray(r2.output))
    # runtime object is reused, not rebuilt per call
    assert eng._runtime(n_little=2, work_stealing=True) is \
        eng._runtime(n_little=2, work_stealing=True)


def test_deferred_stage_traces_complete_before_result(tiny_engine):
    """stage_in_prep=False: 'any'-affinity staging (the old stager threads)
    must land every trace before the job completes, exactly once per
    weighted layer, and strictly before the layer's execute."""
    eng, x = tiny_engine
    rt = eng.make_runtime(n_little=2)
    rt.stage_in_prep = False
    res = rt.run(np.asarray(x, np.float32), eng.plan)
    n = len(res.traces)
    time.sleep(0.05)
    assert len(res.traces) == n
    weighted = {l.spec.name for l in eng.layers if l.spec.weight_shapes}
    stage = [t for t in res.traces if t.kind == "stage"]
    assert {t.layer for t in stage} == weighted and len(stage) == len(weighted)
    exec_start = {t.layer: t.start for t in res.traces if t.kind == "execute"}
    for t in stage:
        assert t.end <= exec_start[t.layer] + 1e-9


def test_graph_hook_extends_job(tiny_engine):
    """Extra tasks appended via graph_hook (the LLM bridge's mechanism) run
    on the pool, record their kind, and gate job completion."""
    eng, x = tiny_engine
    seen = []

    def hook(graph, weights, lock):
        for t in [t for t in graph.tasks if t.kind == "execute"]:
            graph.add(t.layer, "pack", affinity="any", deps=(t.tid,),
                      fn=lambda name=t.layer: seen.append(name))

    job = eng.submit_cold(x, n_little=2, graph_hook=hook)
    res = job.result(30)
    assert set(seen) == {l.spec.name for l in eng.layers}
    packs = [t for t in res.traces if t.kind == "pack"]
    assert len(packs) == len(eng.layers)
    ex_end = {t.layer: t.end for t in res.traces if t.kind == "execute"}
    for t in packs:
        assert t.start >= ex_end[t.layer] - 1e-9


# ---------------------------------------------------------------------------
# satellite: big/little core pinning (sched_setaffinity, clean no-op fallback)
# ---------------------------------------------------------------------------
def test_cpuset_split_big_top_little_bottom(monkeypatch):
    import os

    from repro.executor import pool as pool_mod

    monkeypatch.setattr(pool_mod, "_HAS_AFFINITY", True)
    monkeypatch.setattr(os, "sched_getaffinity", lambda pid: {0, 1, 2, 3},
                        raising=False)
    p = CorePool(n_big=2, n_little=3, name="cpuset", pin_cores=True)
    try:
        # top half of the allowed cores -> big lanes, bottom half -> little;
        # worker indices wrap within their half
        assert p._cpuset_for("big", 0) == {2}
        assert p._cpuset_for("big", 1) == {3}
        assert p._cpuset_for("big", 2) == {2}
        assert p._cpuset_for("little", 0) == {0}
        assert p._cpuset_for("little", 1) == {1}
        assert p._cpuset_for("little", 2) == {0}
        # big and little cpu sets never overlap
        bigs = p._cpuset_for("big", 0) | p._cpuset_for("big", 1)
        littles = p._cpuset_for("little", 0) | p._cpuset_for("little", 1)
        assert not (bigs & littles)
    finally:
        p.shutdown()


def test_workers_pin_on_entry_and_record(monkeypatch):
    import os
    import threading

    from repro.executor import pool as pool_mod

    pins = {}

    def fake_set(pid, cpus):
        pins[threading.current_thread().name] = set(cpus)

    monkeypatch.setattr(pool_mod, "_HAS_AFFINITY", True)
    monkeypatch.setattr(os, "sched_getaffinity", lambda pid: {0, 1, 2, 3},
                        raising=False)
    monkeypatch.setattr(os, "sched_setaffinity", fake_set, raising=False)
    p = CorePool(n_big=1, n_little=2, name="pin", pin_cores=True)
    try:
        p.submit(_prep_graph([["a"], ["b"]]), name="warm").wait(10)
        # every spawned worker pinned itself and recorded the outcome
        assert p.pinned and all(v is not None for v in p.pinned.values())
        for tname, cpus in pins.items():
            assert p.pinned[tname] == sorted(cpus)
    finally:
        p.shutdown()


def test_pinning_is_clean_noop_without_affinity_api(monkeypatch):
    from repro.executor import pool as pool_mod

    monkeypatch.setattr(pool_mod, "_HAS_AFFINITY", False)
    p = CorePool(n_big=1, n_little=2, name="nopin", pin_cores=True)
    try:
        job = p.submit(_prep_graph([["a"], ["b"]]), name="run")
        job.wait(10)
        assert job.error is None
        # outcome recorded as "not pinned", nothing raised anywhere
        assert p.pinned and all(v is None for v in p.pinned.values())
    finally:
        p.shutdown()
