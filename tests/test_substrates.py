"""Optimizer, checkpoint IO, data pipeline, sharding specs, hlo_cost."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import LayerStore, load_pytree, save_pytree
from repro.configs import get_config
from repro.data import SyntheticPipeline
from repro.optim import adamw_init, adamw_update, cosine_lr


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    opt = adamw_init(params)
    lr = lambda step: 0.1
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, opt, m = adamw_update(grads, opt, params, lr=lr,
                                      weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_grad_clipping():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    grads = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, metrics = adamw_update(grads, opt, params, lr=0.1, clip_norm=1.0)
    assert metrics["grad_norm"] > 1e5  # reported pre-clip


def test_cosine_schedule_shape():
    lr = cosine_lr(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 1e-6


def test_layer_store_roundtrip(tmp_path):
    st = LayerStore(tmp_path)
    w = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    st.write_raw("layer0", w)
    back = st.read_raw("layer0")
    np.testing.assert_array_equal(back["w"], w["w"])
    st.write_cached("layer0", "wino", {"u": np.ones((2, 2), np.float32)})
    assert st.has_cached("layer0", "wino")
    assert st.cache_bytes() > 0
    st.drop_cached("layer0", "wino")
    assert not st.has_cached("layer0", "wino")


def test_pytree_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.ones((2, 3), jnp.bfloat16),
            "b": {"c": jnp.arange(4, dtype=jnp.int32)}}
    save_pytree(tmp_path / "ckpt", tree)
    back = load_pytree(tmp_path / "ckpt", tree)
    assert back["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_pytree_checkpoint_bf16_values_roundtrip(tmp_path):
    """bf16 leaves are detected explicitly (ml_dtypes), widened to f32 on
    disk, and restored to bf16 with identical values on load."""
    rng = np.random.default_rng(7)
    vals = jnp.asarray(rng.standard_normal((4, 5)), jnp.bfloat16)
    tree = {"w": vals}
    save_pytree(tmp_path / "ckpt", tree)
    back = load_pytree(tmp_path / "ckpt", tree)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"]).view(np.uint16),
                                  np.asarray(vals).view(np.uint16))


def test_pytree_checkpoint_rejects_structured_dtypes(tmp_path):
    """Regression: any void-kind dtype used to be silently widened and
    mislabeled as bfloat16; structured dtypes must raise instead."""
    bad = np.zeros(3, dtype=np.dtype([("a", np.int32), ("b", np.float32)]))
    with pytest.raises(TypeError, match="unsupported dtype"):
        save_pytree(tmp_path / "ckpt", {"bad": bad})


def test_pipeline_deterministic_and_microbatched():
    cfg = get_config("smollm-360m").reduced()
    p1 = SyntheticPipeline(cfg, batch=8, seq=16, microbatches=2, seed=3)
    p2 = SyntheticPipeline(cfg, batch=8, seq=16, microbatches=2, seed=3)
    b1, b2 = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (2, 4, 16)
    assert int(b1["tokens"].max()) < cfg.vocab_size


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------
def test_param_specs_divisibility_fallback():
    from jax.sharding import PartitionSpec as P

    from repro.launch.specs import params_shape
    from repro.models.sharding import default_strategy, param_specs

    cfg = get_config("smollm-360m")  # 15 heads, 5 kv heads: not 16-divisible
    pshape = params_shape(cfg)
    specs = param_specs(pshape, cfg, {"data": 16, "model": 16},
                        default_strategy())
    # attention projections must fall back to head-aligned replication
    wq_spec = specs["blocks"]["attn"]["wq"]
    assert wq_spec[-1] is None  # 15 heads % 16 != 0 -> replicate
    # mlp ffn (2560) is divisible -> sharded on model
    assert specs["blocks"]["mlp"]["w_gate"][-1] == "model"
    # vocab 49152 divisible -> embed sharded
    assert specs["embed"][0] == "model"


def test_param_specs_structure_matches_params():
    from repro.launch.specs import params_shape
    from repro.models.sharding import param_specs

    for arch in ["qwen3-moe-30b-a3b", "mamba2-2.7b", "zamba2-2.7b"]:
        cfg = get_config(arch)
        pshape = params_shape(cfg)
        specs = param_specs(pshape, cfg, {"data": 16, "model": 16})
        assert jax.tree.structure(
            pshape, is_leaf=lambda x: hasattr(x, "shape")) is not None
        # spec ndim == leaf ndim everywhere
        def chk(leaf, spec):
            assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        jax.tree.map(chk, pshape, specs,
                     is_leaf=lambda x: hasattr(x, "shape"))


# ---------------------------------------------------------------------------
# hlo_cost
# ---------------------------------------------------------------------------
def test_hlo_cost_matches_xla_loop_free():
    from repro.roofline.hlo_cost import analyze

    x = jnp.ones((256, 256), jnp.bfloat16)
    c = jax.jit(lambda a, b: a @ b).lower(x, x).compile()
    mine = analyze(c.as_text())
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):  # newer JAX returns [dict]
        xla = xla[0]
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.05
    assert abs(mine.hbm_bytes - xla["bytes accessed"]) / xla["bytes accessed"] < 0.2


def test_hlo_cost_multiplies_scan_trips():
    from repro.roofline.hlo_cost import analyze

    x = jnp.ones((128, 128), jnp.bfloat16)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    c = jax.jit(scanned).lower(x, x).compile()
    single = jax.jit(lambda a, b: a @ b).lower(x, x).compile()
    f_scan = analyze(c.as_text()).flops
    f_one = analyze(single.as_text()).flops
    assert 6.5 < f_scan / f_one < 7.5


def test_collective_wire_bytes_parse():
    from repro.roofline.hlo_cost import analyze

    txt = """
HloModule test

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups=[1,8]<=[8], to_apply=%add
}
"""
    c = analyze(txt)
    # 2 * 4096 bytes * 7/8
    assert abs(c.wire_bytes - 2 * 4096 * 7 / 8) < 1.0
    assert "all-reduce" in c.wire_by_kind
