"""Scheduler correctness + properties.

- Algorithm 1 vs exhaustive optimum on tiny graphs (near-optimality claim);
- hypothesis property tests on the simulator invariants: dependency order,
  makespan bounds (>= critical path, <= sequential), work-stealing never
  hurts the makespan in the simulator.
"""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (
    Choice, LayerCandidates, brute_force_optimal, inner_schedule,
    pareto_filter, schedule, schedule_annealed, simulate,
)


def _mk_cands(prep_exec):
    """prep_exec: list per layer of [(prep_little, prep_big, exec)]."""
    out = []
    for li, opts in enumerate(prep_exec):
        out.append(LayerCandidates(
            layer=f"l{li}",
            options=[(Choice(f"k{i}", False), pl, pb, ex)
                     for i, (pl, pb, ex) in enumerate(opts)],
        ))
    return out


def test_pareto_filter_drops_dominated():
    c = [(Choice("a", False), 1.0, 1.0), (Choice("b", False), 2.0, 2.0),
         (Choice("c", False), 0.5, 3.0)]
    kept = pareto_filter(c)
    names = {x[0].kernel for x in kept}
    assert names == {"a", "c"}


def test_algorithm1_near_optimal_small():
    """Winograd-vs-sgemm style trade-offs on 5 layers: Algorithm 1 within
    15% of the brute-force optimum."""
    import random

    rng = random.Random(0)
    for trial in range(10):
        cands = _mk_cands([
            [(rng.uniform(1, 5), rng.uniform(0.5, 2), rng.uniform(0.2, 2)),
             (rng.uniform(0.2, 1), rng.uniform(0.1, 0.5), rng.uniform(1, 4))]
            for _ in range(5)
        ])
        heur = schedule(cands, M_l=2)
        opt = brute_force_optimal(cands, M_l=2)
        assert heur.est_makespan <= opt.est_makespan * 1.15 + 1e-9, \
            (trial, heur.est_makespan, opt.est_makespan)


def test_schedule_beats_sequential():
    cands = _mk_cands([[(1.0, 0.5, 0.5)] for _ in range(8)])
    plan = schedule(cands, M_l=3)
    sequential = sum(0.5 + 0.5 for _ in range(8))  # big-core prep + exec
    assert plan.est_makespan <= sequential + 1e-9


@given(
    st.lists(
        st.tuples(
            st.floats(0.01, 5), st.floats(0.01, 5), st.floats(0.01, 5)),
        min_size=1, max_size=12),
    st.integers(1, 4),
)
@settings(max_examples=60, deadline=None)
def test_simulator_invariants(layers, M_l):
    pl = [a for a, b, e in layers]
    pb = [b for a, b, e in layers]
    ex = [e for a, b, e in layers]
    big_prep, qs, mk = inner_schedule(pl, pb, ex, M_l)
    N = len(layers)
    # every layer prepped exactly once
    allp = sorted(big_prep + [i for q in qs for i in q])
    assert allp == list(range(N))
    # makespan >= exec critical path; <= fully sequential on big
    assert mk >= sum(ex) - 1e-9
    assert mk <= sum(pb) + sum(ex) + sum(pl) + 1e-6
    # work stealing never slows the simulated makespan
    mk_ws, _ = simulate(pl, pb, ex, big_prep, qs, work_stealing=True)
    assert mk_ws <= mk * 1.5 + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_annealing_not_better_than_bruteforce(seed):
    import random

    rng = random.Random(seed)
    cands = _mk_cands([
        [(rng.uniform(0.1, 3), rng.uniform(0.1, 2), rng.uniform(0.1, 3))
         for _ in range(2)]
        for _ in range(4)
    ])
    opt = brute_force_optimal(cands, M_l=2)
    ann = schedule_annealed(cands, M_l=2, iters=300, seed=seed)
    assert ann.est_makespan >= opt.est_makespan - 1e-9


def test_workload_stealing_recovers_busy_core():
    """Fig. 11 semantics: with a loaded little core, stealing must beat
    sticking to the static plan."""
    pl = [1.0] * 8
    pb = [0.5] * 8
    ex = [0.1] * 8
    big_prep, qs, _ = inner_schedule(pl, pb, ex, M_l=2)
    load = {0: 4.0}  # little core 0 is 4x slower (50% bg load on 2 HT...)
    mk_static, _ = simulate(pl, pb, ex, big_prep, qs, core_load=load,
                            work_stealing=False)
    mk_steal, _ = simulate(pl, pb, ex, big_prep, qs, core_load=load,
                           work_stealing=True)
    assert mk_steal <= mk_static + 1e-9
