"""Multi-device correctness of the shard_map flash-decoding path.

Runs in a subprocess with 8 forced host devices (the main test process must
keep 1 device), building a (data=2, model=4) mesh and checking that the
sharded decode step matches the single-device reference bitwise-closely."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding, AxisType
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models import sharding as SH
    from repro.models.runtime_flags import FLAGS

    cfg = get_config("qwen3-32b").reduced(
        num_layers=2, num_heads=4, num_kv_heads=2, d_model=256, head_dim=64,
        vocab_size=512)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, W = 4, 32
    toks = jax.random.randint(key, (B, 5), 0, cfg.vocab_size)

    # reference: single device, plain path
    FLAGS["decode_flash"] = False
    state = T.init_decode_state(cfg, B, W)
    outs = []
    st = state
    for t in range(5):
        lg, st = T.decode_step(params, st, {"tokens": toks[:, t:t+1]},
                               jnp.int32(t), cfg)
        outs.append(np.asarray(lg))
    ref = np.stack(outs)

    # sharded: mesh (data=2, model=4), flash decode ON
    FLAGS["decode_flash"] = True
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    mshape = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = SH.param_specs(jax.eval_shape(lambda: params), cfg, mshape)
    sspecs = SH.decode_state_specs(jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, W)), cfg, mshape)
    named = lambda s: SH.to_named(s, mesh)
    with mesh:
        params_s = jax.device_put(params, named(pspecs))
        st = jax.device_put(T.init_decode_state(cfg, B, W), named(sspecs))
        step = jax.jit(lambda p, s, b, pos: T.decode_step(p, s, b, pos, cfg),
                       in_shardings=(named(pspecs), named(sspecs), None, None),
                       donate_argnums=(1,))
        outs2 = []
        for t in range(5):
            lg, st = step(params_s, st, {"tokens": toks[:, t:t+1]},
                          jnp.int32(t))
            outs2.append(np.asarray(lg))
    got = np.stack(outs2)
    err = float(np.abs(got - ref).max())
    # verify the sharded path actually engaged (cache seq dim sharded)
    seq_sharded = "model" in str(st["k"].sharding)
    print("RESULT", json.dumps({"err": err, "seq_sharded": bool(seq_sharded)}))
""")


def test_flash_decode_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", "import json\n" + SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    res = json.loads(line.split("RESULT ")[1])
    assert res["err"] < 0.05, res
