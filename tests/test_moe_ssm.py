"""MoE grouped-FFN + Mamba2 SSD layer correctness (incl. property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.moe import _grouped_ffn, grouped_matmul
from repro.models.ssm import causal_conv, ssd_chunked, ssd_decode_step
from repro.kernels.ref import ssd_naive_ref

RNG = np.random.default_rng(0)


def _ragged_ref(xs, gs, wg, wu, wd):
    h = jax.nn.silu(jax.lax.ragged_dot(xs, wg, gs)) * jax.lax.ragged_dot(xs, wu, gs)
    return jax.lax.ragged_dot(h, wd, gs)


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_grouped_ffn_matches_ragged(seed):
    rng = np.random.default_rng(seed)
    E, d, ff = 4, 8, 16
    sizes = rng.multinomial(32, np.ones(E) / E)
    gs = jnp.asarray(sizes, jnp.int32)
    M = int(sizes.sum())
    xs = jnp.asarray(rng.standard_normal((M, d)).astype(np.float32))
    wg = jnp.asarray(rng.standard_normal((E, d, ff)).astype(np.float32)) * 0.2
    wu = jnp.asarray(rng.standard_normal((E, d, ff)).astype(np.float32)) * 0.2
    wd = jnp.asarray(rng.standard_normal((E, ff, d)).astype(np.float32)) * 0.2
    # capacity >= max group: no drops -> exact match
    C = max(8, int(np.ceil(sizes.max() / 8.0)) * 8)
    y = _grouped_ffn(xs, gs, wg, wu, wd, C)
    ref = _ragged_ref(xs, gs, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_grouped_ffn_gradients_match():
    E, d, ff, M = 3, 8, 16, 24
    gs = jnp.array([10, 6, 8], jnp.int32)
    xs = jnp.asarray(RNG.standard_normal((M, d)).astype(np.float32))
    wg = jnp.asarray(RNG.standard_normal((E, d, ff)).astype(np.float32)) * 0.2
    wu = jnp.asarray(RNG.standard_normal((E, d, ff)).astype(np.float32)) * 0.2
    wd = jnp.asarray(RNG.standard_normal((E, ff, d)).astype(np.float32)) * 0.2
    f = lambda xs, wg, wu, wd: (_grouped_ffn(xs, gs, wg, wu, wd, 16) ** 2).sum()
    g = lambda xs, wg, wu, wd: (_ragged_ref(xs, gs, wg, wu, wd) ** 2).sum()
    ga = jax.grad(f, argnums=(0, 1, 2, 3))(xs, wg, wu, wd)
    gb = jax.grad(g, argnums=(0, 1, 2, 3))(xs, wg, wu, wd)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_grouped_matmul_custom_vjp():
    gs = jnp.array([4, 5, 3])
    x = jnp.asarray(RNG.standard_normal((12, 8)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((3, 8, 6)).astype(np.float32))
    f = lambda x, w: (grouped_matmul(x, w, gs) ** 2).sum()
    fr = lambda x, w: (jax.lax.ragged_dot(x, w, gs) ** 2).sum()
    ga = jax.grad(f, argnums=(0, 1))(x, w)
    gb = jax.grad(fr, argnums=(0, 1))(x, w)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity below the max group size, overflow tokens contribute 0."""
    E, d, ff = 2, 4, 8
    gs = jnp.array([12, 0], jnp.int32)
    xs = jnp.ones((12, d), jnp.float32)
    wg = jnp.ones((E, d, ff), jnp.float32) * 0.1
    wu = jnp.ones((E, d, ff), jnp.float32) * 0.1
    wd = jnp.ones((E, ff, d), jnp.float32) * 0.1
    y = _grouped_ffn(xs, gs, wg, wu, wd, 8)
    # first 8 rows computed, rows 8..11 dropped (zero)
    assert float(jnp.abs(y[:8]).min()) > 0
    assert float(jnp.abs(y[8:]).max()) == 0.0


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_chunked_matches_naive(chunk):
    B, S, H, P, N = 2, 128, 3, 16, 8
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)).astype(np.float32)) * 0.3
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, S, H))).astype(np.float32) * 0.3)
    A = -jnp.asarray(np.linspace(0.5, 2.0, H), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, 1, N)).astype(np.float32)) * 0.3
    Cm = jnp.asarray(RNG.standard_normal((B, S, 1, N)).astype(np.float32)) * 0.3
    D = jnp.ones((H,), jnp.float32)
    y, st_c = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    yr, st_r = ssd_naive_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r), atol=2e-4,
                               rtol=2e-3)


def test_ssd_decode_continues_sequence():
    """Chunked over S tokens == chunked over S-1 + one decode step."""
    B, S, H, P, N = 1, 64, 2, 8, 4
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)).astype(np.float32)) * 0.3
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, S, H))).astype(np.float32) * 0.3)
    A = -jnp.asarray(np.linspace(0.5, 2.0, H), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, 1, N)).astype(np.float32)) * 0.3
    Cm = jnp.asarray(RNG.standard_normal((B, S, 1, N)).astype(np.float32)) * 0.3
    D = jnp.ones((H,), jnp.float32)
    y_full, _ = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    _, st = ssd_chunked(x[:, :48], dt[:, :48], A, Bm[:, :48], Cm[:, :48], D,
                        chunk=16)
    ys = []
    for t in range(48, S):
        y1, st = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, st)
        ys.append(y1)
    dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(y_full[:, 48:]),
                               atol=2e-4, rtol=2e-3)


def test_causal_conv_state_continuity():
    B, S, C, K = 2, 32, 6, 4
    x = jnp.asarray(RNG.standard_normal((B, S, C)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((K, C)).astype(np.float32))
    y_full, _ = causal_conv(x, w)
    y1, st = causal_conv(x[:, :20], w)
    y2, _ = causal_conv(x[:, 20:], w, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
