"""Hypothesis property tests on the layer library invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import _quantize_kv, rms_norm, rope


@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.sampled_from([32, 64]))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm(seed, B, D):
    """Rotary embedding is a rotation: per-head L2 norm is invariant."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, 6, 2, D)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, 10_000, size=(B, 6)).astype(np.int32))
    y = rope(x, pos, 10_000.0)
    n1 = jnp.linalg.norm(x, axis=-1)
    n2 = jnp.linalg.norm(y, axis=-1)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_rope_relative_position_property(seed):
    """<rope(q,p), rope(k,p)> depends only on the position difference."""
    rng = np.random.default_rng(seed)
    D = 64
    q = jnp.asarray(rng.standard_normal((1, 1, 1, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 1, 1, D)).astype(np.float32))

    def score(pq, pk):
        qr = rope(q, jnp.full((1, 1), pq, jnp.int32), 10_000.0)
        kr = rope(k, jnp.full((1, 1), pk, jnp.int32), 10_000.0)
        return float(jnp.sum(qr * kr))

    d = int(rng.integers(0, 50))
    off = int(rng.integers(0, 1000))
    assert abs(score(7 + d, 7) - score(off + d, off)) < 1e-2


@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_rms_norm_scale_invariance(seed, alpha):
    """rms_norm(alpha * x) == rms_norm(x)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 5, 16)).astype(np.float32)) + 0.1
    g = jnp.asarray(rng.standard_normal(16).astype(np.float32))
    y1 = rms_norm(x, g)
    y2 = rms_norm(x * alpha, g)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 50.0))
@settings(max_examples=25, deadline=None)
def test_int8_quantization_error_bound(seed, scale):
    """Absolute dequantization error <= absmax/127 per (entry, head)."""
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((2, 1, 3, 32)).astype(np.float32)) * scale
    q, s = _quantize_kv(k)
    back = q.astype(jnp.float32) * s[..., None]
    amax = np.abs(np.asarray(k)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(k))
    assert (err <= amax / 127.0 * 0.51 + 1e-6).all()
