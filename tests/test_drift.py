"""Host-fingerprint drift: a ProfileDB measured under another fingerprint
(same machine after a jax upgrade / CPU-count change) serves its entries
as STALE fallbacks — the cold path never re-profiles in-line — and the
background path (``ColdEngine.reprofile_stale``, driven by the server's
idle tick) re-measures them off the request path."""
import json

import pytest

from repro.core.engine import ColdEngine
from repro.core.profiler import OpProfile, ProfileDB
from repro.models.cnn import build_cnn

FAKE_HOST = "cafe0123deadbeef"


def _prof(layer="l0", kernel="k"):
    return OpProfile(layer=layer, kernel=kernel, read_raw_s=1.0,
                     transform_s=0.1, read_cached_s=0.5, exec_s=0.2,
                     compile_s=0.3, raw_bytes=100, transformed_bytes=80)


def _drift_db_file(path):
    """Rewrite a saved DB as if every entry was measured on another host."""
    raw = json.loads(path.read_text())
    raw["hosts"] = {FAKE_HOST: v for v in raw["hosts"].values()}
    raw["siblings"] = {FAKE_HOST: v for v in raw.get("siblings", {}).values()}
    path.write_text(json.dumps(raw))


def test_drifted_entries_serve_stale_and_unstale_on_put(tmp_path):
    p = tmp_path / "db.json"
    db = ProfileDB(p)
    db.put("sc1", "k", _prof())
    db.put("sc2", "k", _prof())
    db.save()
    _drift_db_file(p)

    db2 = ProfileDB(p)
    assert db2.entries == {}                      # nothing fresh
    assert db2.drifted_from == FAKE_HOST
    got = db2.get("sc1", "k")
    assert got is not None and got.read_raw_s == 1.0  # stale entry serves
    assert db2.stats["stale_hits"] == 1
    assert db2.stale == {("sc1", "k")}
    assert db2.stale_pending() == [("sc1", "k")]
    # a fresh measurement supersedes the drifted fallback
    db2.put("sc1", "k", _prof())
    assert db2.stale == set()
    assert db2.get("sc1", "k") is not None
    assert db2.stats["hits"] == 1
    # saving keeps the donor host's entries side by side
    db2.save()
    hosts = json.loads(p.read_text())["hosts"]
    assert FAKE_HOST in hosts and db2.host in hosts


def test_no_drift_adoption_when_current_host_has_entries(tmp_path):
    p = tmp_path / "db.json"
    db = ProfileDB(p)
    db.put("sc1", "k", _prof())
    db.save()
    # add a second host WITHOUT wiping ours: no drift, no stale serving
    raw = json.loads(p.read_text())
    raw["hosts"][FAKE_HOST] = {"scX": {"k": raw["hosts"][db.host]
                                       ["sc1"]["k"]}}
    p.write_text(json.dumps(raw))
    db2 = ProfileDB(p)
    assert db2.drifted_from is None
    assert db2.get("scX", "k") is None            # other host stays invisible
    assert db2.stats["stale_hits"] == 0


@pytest.fixture
def drifted_engine(tmp_path):
    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    dbp = tmp_path / "shared_db.json"
    eng = ColdEngine(layers, tmp_path / "store_a", profile_db=str(dbp))
    eng.decide(x, n_little=2)
    _drift_db_file(dbp)
    eng2 = ColdEngine(layers, tmp_path / "store_b", profile_db=str(dbp))
    return eng2, x, dbp


def test_decide_serves_stale_without_inline_reprofiling(drifted_engine):
    eng2, x, _ = drifted_engine
    stats = eng2.decide(x, n_little=2)
    # the cold path paid ZERO profiler calls — every class came from the
    # drifted host's measurements, flagged for background refresh
    assert stats["profile_calls"] == 0
    assert stats["profile_db_stale_hits"] > 0
    assert eng2._stale_reps                       # work list populated
    assert eng2.profile_db.stale_pending()


def test_reprofile_stale_refreshes_off_cold_path(drifted_engine):
    eng2, x, dbp = drifted_engine
    eng2.decide(x, n_little=2)
    n_stale = len(eng2._stale_reps)
    # bounded: one class per idle tick
    assert eng2.reprofile_stale(max_classes=1) == 1
    assert len(eng2._stale_reps) == n_stale - 1
    # drain the rest
    while eng2.reprofile_stale(max_classes=1):
        pass
    assert eng2._stale_reps == {}
    assert eng2.profile_db.stale_pending() == []
    assert eng2.repairs.of_kind("reprofile_drift")
    # fresh measurements landed under the CURRENT host fingerprint
    hosts = json.loads(dbp.read_text())["hosts"]
    assert hosts.get(eng2.profile_db.host)
    # a third engine now decides fully fresh: no stale hits at all
    layers, _ = build_cnn("mobilenet", image=16, width=0.25)
    eng3 = ColdEngine(layers, dbp.parent / "store_c", profile_db=str(dbp))
    stats = eng3.decide(x, n_little=2)
    assert stats["profile_db_stale_hits"] == 0
    assert stats["profile_calls"] == 0            # fresh DB hits instead


def test_server_idle_tick_reprofiles_one_class(tmp_path):
    from repro.executor.server import ColdServer

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    seed = ColdEngine(layers, tmp_path / "seed",
                      profile_db=str(tmp_path / "fd_db.json"))
    seed.decide(x, n_little=2)
    _drift_db_file(tmp_path / "fd_db.json")

    srv = ColdServer(tmp_path / "srv", n_little=2, share_profile_db=True)
    srv.profile_db = ProfileDB(tmp_path / "fd_db.json")
    srv.add_model("mnet", layers)
    srv.decide("mnet", x, n_little=2)
    eng = srv.engines["mnet"]
    assert eng._stale_reps
    before = len(eng._stale_reps)
    srv._idle_tick(["mnet"], 0)                   # one idle tick
    assert srv.stats["idle_reprofiles"] == 1
    assert len(eng._stale_reps) == before - 1     # bounded: one per tick
