"""Deadline plumbing end to end: pre-admission shedding, admission-wait
budget decay, the pool watchdog's end-to-end job deadline, caller-side
wait timeouts (``JobTimeout``) — and that none of these paths leak an
admission slot, a pool job, or an in-flight read."""
import numpy as np
import pytest

from repro.executor.graph import TaskGraph
from repro.executor.pool import CorePool
from repro.executor.server import ColdServer
from repro.faults import DeadlineExceeded, JobTimeout, ModelQuarantined
from repro.models.cnn import build_cnn


@pytest.fixture
def server(tmp_path):
    pool = CorePool(n_little=2, n_big=1, name="deadline-test")
    srv = ColdServer(tmp_path / "srv", pool=pool, n_little=2)
    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    srv.add_model("mnet", layers)
    srv.decide("mnet", x, n_little=2)
    yield srv, x
    # the leak gate: every test path must leave the pool joinable —
    # a stuck worker thread here fails the test as a typed WorkerLost
    pool.shutdown(timeout=10.0, raise_on_leak=True)
    assert srv.stats["active_preps"] == 0


def test_zero_budget_shed_before_admission(server):
    srv, x = server
    before = dict(srv.stats)
    with pytest.raises(DeadlineExceeded):
        srv.cold_start("mnet", x, deadline_s=0.0)
    with pytest.raises(DeadlineExceeded):
        srv.cold_start("mnet", x, deadline_s=-1.0)
    # shed BEFORE the semaphore: nothing admitted, nothing outstanding
    assert srv.stats["admitted"] == before["admitted"]
    assert srv._outstanding == 0
    # and the server still serves normally afterwards
    res = srv.cold_start("mnet", x).result()
    assert res.output is not None


def test_wait_timeout_is_typed_and_releases_nothing_held(server):
    srv, x = server
    h = srv.cold_start("mnet", x)
    with pytest.raises(JobTimeout):
        h.result(timeout=1e-6)
    # JobTimeout is a TimeoutError for pre-taxonomy callers
    assert issubclass(JobTimeout, TimeoutError)
    # the caller's wait gave up but the job is unharmed: a second wait
    # completes, the admission slot frees on its own, no quarantine
    res = h.result()
    assert res.output is not None
    assert srv._model_quarantine == {}
    assert srv.stats["active_preps"] == 0
    if srv.io_engine is not None:
        assert srv.io_engine.reads_in_flight() == 0


def test_job_deadline_expiry_typed_slot_released_no_quarantine(server):
    srv, x = server
    with pytest.raises(DeadlineExceeded):
        srv.cold_start("mnet", x, deadline_s=1e-4).result()
    # watchdog accounting is visible pool-wide
    assert srv.pool.health["job_deadline_expired"] >= 1
    # deadline pressure must NOT quarantine a healthy model ...
    assert srv._model_quarantine == {}
    # ... and the admission slot came back: an unbudgeted request runs
    res = srv.cold_start("mnet", x).result()
    assert res.output is not None
    assert srv.stats["active_preps"] == 0
    assert srv._outstanding == 0


def test_admission_wait_decays_budget(tmp_path):
    pool = CorePool(n_little=2, n_big=1, name="decay-test")
    srv = ColdServer(tmp_path / "srv", pool=pool, n_little=2,
                     max_concurrent_preps=1)
    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    srv.add_model("mnet", layers)
    srv.decide("mnet", x, n_little=2)
    try:
        first = srv.cold_start("mnet", x)   # holds the single prep slot
        # a tiny budget cannot survive queueing behind `first`: by the
        # time the slot frees, the budget is gone -> typed shed, slot
        # RELEASED (the follow-up request proves it)
        with pytest.raises(DeadlineExceeded):
            h = srv.cold_start("mnet", x, deadline_s=2e-3)
            h.result()
        first.result()
        res = srv.cold_start("mnet", x, deadline_s=60.0).result()
        assert res.output is not None
        assert srv.stats["active_preps"] == 0
    finally:
        pool.shutdown(timeout=10.0, raise_on_leak=True)


def test_drain_refuses_then_resume_reopens(server):
    srv, x = server
    srv.cold_start("mnet", x).result()
    assert srv.drain(timeout=10.0) is True
    with pytest.raises(RuntimeError):
        srv.cold_start("mnet", x)
    assert srv.health()["draining"] is True
    srv.resume()
    res = srv.cold_start("mnet", x).result()
    assert res.output is not None


def test_pool_drain_and_resume():
    pool = CorePool(n_little=1, n_big=1, name="drain-test")
    try:
        assert pool.drain(timeout=1.0) is True   # nothing in flight
        with pytest.raises(RuntimeError, match="draining"):
            pool.submit(TaskGraph(), name="refused")
        pool.resume()
        pool.submit(TaskGraph(), name="ok").wait(5.0)
    finally:
        pool.shutdown(timeout=5.0, raise_on_leak=True)
