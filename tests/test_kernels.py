"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret=True on CPU)
vs its pure-jnp oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.attention import decode_attention, flash_attention
from repro.kernels.conv_winograd import winograd_tile_matmul
from repro.kernels.matmul import matmul, matmul_packed
from repro.kernels.ssd import ssd_scan

RNG = np.random.default_rng(0)


def _tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 5e-4


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (200, 300, 150),
                                   (64, 512, 96), (1, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(M, K, N, dtype):
    x = jnp.asarray(RNG.standard_normal((M, K)), dtype)
    w = jnp.asarray(RNG.standard_normal((K, N)), dtype)
    y = matmul(x, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(R.matmul_ref(x, w), np.float32),
        atol=_tol(dtype) * np.sqrt(K), rtol=_tol(dtype))


@pytest.mark.parametrize("K,N", [(300, 150), (128, 128), (100, 37)])
def test_matmul_packed_sweep(K, N):
    from repro.core.registry import LayerSpec, LinearPacked

    x = jnp.asarray(RNG.standard_normal((64, K)), jnp.float32)
    w = RNG.standard_normal((K, N)).astype(np.float32)
    spec = LayerSpec("l", "linear", {"in_features": K, "out_features": N},
                     {"w": (K, N)})
    packed = jnp.asarray(LinearPacked().transform({"w": w}, spec)["w_packed"])
    y = matmul_packed(x, packed, K, N, interpret=True)
    ref = R.matmul_ref(x, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("S,H,KV,D", [(128, 4, 4, 64), (256, 4, 2, 64),
                                      (192, 8, 1, 32)])
@pytest.mark.parametrize("window,softcap", [(None, None), (64, None),
                                            (None, 30.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, D, window, softcap, dtype):
    B = 2
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), dtype) * 0.3
    k = jnp.asarray(RNG.standard_normal((B, S, KV, D)), dtype) * 0.3
    v = jnp.asarray(RNG.standard_normal((B, S, KV, D)), dtype) * 0.3
    y = flash_attention(q, k, v, causal=True, window=window,
                        softcap=softcap, bq=64, bk=64, interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=True, window=window,
                                softcap=softcap)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("S,H,KV,D", [(512, 8, 4, 64), (300, 4, 4, 32),
                                      (256, 8, 2, 128)])
def test_decode_attention_sweep(S, H, KV, D):
    B = 3
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32) * 0.3
    k = jnp.asarray(RNG.standard_normal((B, S, KV, D)), jnp.float32) * 0.3
    v = jnp.asarray(RNG.standard_normal((B, S, KV, D)), jnp.float32) * 0.3
    length = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    y = decode_attention(q, k, v, length, bs=128, interpret=True)
    ref = R.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("S,H,P,N,chunk", [(256, 4, 64, 32, 64),
                                           (128, 2, 32, 16, 32),
                                           (192, 4, 64, 64, 64)])
def test_ssd_sweep(S, H, P, N, chunk):
    B = 2
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)), jnp.float32) * 0.3
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, S, H))) * 0.3, jnp.float32)
    A = -jnp.asarray(np.linspace(0.5, 2.0, H), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32) * 0.3
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32) * 0.3
    D = jnp.ones((H,), jnp.float32)
    y = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    ref, _ = R.ssd_naive_ref(x, dt, A, Bm[:, :, None, :], Cm[:, :, None, :], D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("T,C,O", [(200, 48, 72), (128, 128, 64), (60, 17, 9)])
def test_winograd_tile_matmul_sweep(T, C, O):
    V = jnp.asarray(RNG.standard_normal((16, T, C)), jnp.float32)
    U = jnp.asarray(RNG.standard_normal((16, C, O)), jnp.float32)
    y = winograd_tile_matmul(V, U, bt=64, bc=64, interpret=True)
    ref = R.winograd_tile_matmul_ref(V, U)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("E,C,d,n", [(4, 64, 32, 48), (8, 128, 128, 128),
                                     (3, 40, 20, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_blocks_sweep(E, C, d, n, dtype):
    from repro.kernels.gmm import gmm_blocks

    x = jnp.asarray(RNG.standard_normal((E, C, d)), dtype) * 0.3
    w = jnp.asarray(RNG.standard_normal((E, d, n)), dtype) * 0.3
    y = gmm_blocks(x, w, bc=32, bn=32, bk=32, interpret=True)
    ref = R.gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype) * np.sqrt(d), rtol=_tol(dtype))


def test_gmm_matches_grouped_ffn_stage():
    """The kernel computes exactly the expert-block stage that
    models.moe._gffn_blocks runs per expert (one projection)."""
    from repro.kernels.gmm import gmm_blocks

    E, C, d, ff = 4, 32, 16, 24
    x = jnp.asarray(RNG.standard_normal((E, C, d)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((E, d, ff)).astype(np.float32))
    y = gmm_blocks(x, w, bc=16, bn=16, bk=16, interpret=True)
    ref = jnp.einsum("ecd,edn->ecn", x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
