"""Decode-by-steps must reproduce the full-sequence forward logits for every
family — this validates KV ring caches, mamba recurrent states, hybrid
shared-attention caches, and RoPE-at-write consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import transformer as T

FAMS = ["smollm-360m", "gemma2-27b", "mamba2-2.7b", "zamba2-2.7b",
        "granite-moe-3b-a800m", "qwen3-32b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced(ssm_chunk=8)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, _, _ = T.forward(params, {"tokens": toks}, cfg)
    state = T.init_decode_state(cfg, B, S)
    dstep = jax.jit(lambda p, s, b, pos: T.decode_step(p, s, b, pos, cfg))
    outs = []
    for t in range(S):
        lg, state = dstep(params, state, {"tokens": toks[:, t:t + 1]},
                          jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - logits))) < 0.08  # bf16 path tolerance


def test_sliding_window_ring_cache():
    """With a window ring buffer, late-position decode must equal a forward
    pass that masks outside the window."""
    cfg = get_config("smollm-360m").reduced().with_sliding_window(8)
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    B, S = 1, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, _, _ = T.forward(params, {"tokens": toks}, cfg)
    state = T.init_decode_state(cfg, B, S)
    # ring cache length == window
    assert state["k"].shape[2] == 8
    dstep = jax.jit(lambda p, s, b, pos: T.decode_step(p, s, b, pos, cfg))
    outs = []
    for t in range(S):
        lg, state = dstep(params, state, {"tokens": toks[:, t:t + 1]},
                          jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - logits))) < 0.05
