"""Incremental outer search: memoization parity + group moves.

The memoized path must be bit-identical to the unmemoized search (the memo
is a pure cache), group moves must not hurt plan quality, and
``brute_force_optimal`` parity on tiny graphs stays green when the descent
path (rather than exhaustive enumeration) is forced.
"""
import random

import pytest

from repro.core.scheduler import (
    Choice, LayerCandidates, brute_force_optimal, candidate_groups,
    schedule,
)


def _mk_cands(prep_exec):
    out = []
    for li, opts in enumerate(prep_exec):
        out.append(LayerCandidates(
            layer=f"l{li}",
            options=[(Choice(f"k{i}", False), pl, pb, ex)
                     for i, (pl, pb, ex) in enumerate(opts)],
        ))
    return out


def _random_cands(rng, n_layers, n_opts, n_groups=0):
    """Random candidate sets; n_groups > 0 duplicates option VALUES across
    layers, like fanned-out shape-class profiles."""
    base = [
        [(rng.uniform(0.1, 4), rng.uniform(0.05, 2), rng.uniform(0.05, 3))
         for _ in range(n_opts)]
        for _ in range(max(1, n_groups) if n_groups else n_layers)
    ]
    if n_groups:
        rows = [base[i % len(base)] for i in range(n_layers)]
    else:
        rows = base
    return _mk_cands(rows)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("n_groups", [0, 3])
def test_memoized_schedule_equals_unmemoized(seed, n_groups):
    """exhaustive_limit=1 forces the coordinate-descent path; the memo must
    be invisible in the result."""
    rng = random.Random(seed)
    cands = _random_cands(rng, n_layers=10, n_opts=3, n_groups=n_groups)
    a = schedule(cands, M_l=2, exhaustive_limit=1, memoize=True)
    b = schedule(cands, M_l=2, exhaustive_limit=1, memoize=False)
    assert a.est_makespan == b.est_makespan
    assert a.choices == b.choices
    assert a.big_prep == b.big_prep
    assert a.little_queues == b.little_queues


def test_candidate_groups_by_value():
    rng = random.Random(0)
    cands = _random_cands(rng, n_layers=9, n_opts=2, n_groups=3)
    groups = candidate_groups(cands)
    assert sorted(len(g) for g in groups) == [3, 3, 3]
    # distinct-valued layers never group
    assert candidate_groups(_random_cands(rng, 6, 2)) == []


@pytest.mark.parametrize("seed", range(6))
def test_descent_parity_with_exhaustive_outer_tiny(seed):
    """Forcing the incremental descent (no exhaustive enumeration) on tiny
    graphs with duplicated layers stays close to the exhaustive OUTER
    search over the same inner heuristic — isolates descent quality from
    inner_schedule placement quality. The plan can never beat the
    exhaustive minimum (descent visits a subset of combos)."""
    rng = random.Random(seed)
    cands = _random_cands(rng, n_layers=5, n_opts=2, n_groups=2)
    heur = schedule(cands, M_l=2, exhaustive_limit=1)
    exhaustive = schedule(cands, M_l=2)  # 32 combos -> exact outer search
    assert heur.est_makespan >= exhaustive.est_makespan - 1e-12
    assert heur.est_makespan <= exhaustive.est_makespan * 1.15 + 1e-9
    # and the true optimum lower-bounds both
    opt = brute_force_optimal(cands, M_l=2)
    assert exhaustive.est_makespan >= opt.est_makespan - 1e-12


def test_group_moves_never_worse_than_singles_only(monkeypatch):
    """With groups present, the search result is at least as good as the
    old singles-only descent (group moves only ADD probes)."""
    import repro.core.scheduler as S

    rng = random.Random(7)
    cands = _random_cands(rng, n_layers=12, n_opts=3, n_groups=4)
    with_groups = schedule(cands, M_l=3, exhaustive_limit=1)
    monkeypatch.setattr(S, "candidate_groups", lambda lc: [])
    singles = schedule(cands, M_l=3, exhaustive_limit=1)
    assert with_groups.est_makespan <= singles.est_makespan + 1e-9


def test_exhaustive_small_space_unchanged():
    """Small spaces still go through exact enumeration."""
    cands = _mk_cands([[(1.0, 0.5, 0.5), (0.3, 0.2, 1.5)] for _ in range(4)])
    p = schedule(cands, M_l=2)
    q = schedule(cands, M_l=2, memoize=False)
    assert p.est_makespan == q.est_makespan and p.choices == q.choices
