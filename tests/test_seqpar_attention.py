"""Multi-device correctness of sequence-parallel attention (the §Perf
hillclimb change for head counts that don't divide the model axis)."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.runtime_flags import FLAGS

    # 3 heads % 4 model shards != 0 -> baseline replicates attention;
    # seqpar shards the query sequence instead
    # f32 so MoE top-k ties can't flip between code paths (bf16 noise
    # amplifies through routing; the math itself is dtype-agnostic)
    cfg = get_config("granite-moe-3b-a800m").reduced(
        num_layers=2, num_heads=3, num_kv_heads=1, d_model=192, head_dim=64,
        vocab_size=256, num_experts=4, top_k=2, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 4, 64
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    FLAGS["seqpar_attn"] = False
    ref, _, _ = T.forward(params, {"tokens": toks}, cfg)
    ref_loss, _ = T.loss_fn(params, {"tokens": toks}, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    FLAGS["seqpar_attn"] = True
    with mesh:
        got, _, _ = jax.jit(
            lambda p, b: T.forward(p, b, cfg))(params, {"tokens": toks})
        got_loss, _ = jax.jit(
            lambda p, b: T.loss_fn(p, b, cfg))(params, {"tokens": toks})
        g = jax.jit(jax.grad(lambda p, b: T.loss_fn(p, b, cfg)[0]))(
            params, {"tokens": toks})
    err = float(jnp.abs(got - ref).max())
    lerr = abs(float(got_loss) - float(ref_loss))
    gfinite = all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
    print("RESULT", json.dumps({"err": err, "lerr": lerr,
                                "grad_finite": gfinite}))
""")


def test_seqpar_attention_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", "import json\n" + SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    res = json.loads(line.split("RESULT ")[1])
    assert res["err"] < 1e-4, res
    # loss includes the MoE aux term, which is computed per data shard under
    # shard_map (standard local load-balance loss) vs globally on 1 device
    assert res["lerr"] < 5e-3, res
    assert res["grad_finite"], res
