"""ColdServer: multi-model cold serving on one pool — admission control,
shared ProfileDB, LRU residency, and the cold-LLM serving bridge."""
import threading

import numpy as np
import pytest

from repro.executor.server import ColdServer


@pytest.fixture(scope="module")
def two_model_server(tmp_path_factory):
    from repro.models.cnn import build_cnn

    srv = ColdServer(tmp_path_factory.mktemp("srv"), n_little=2,
                     max_concurrent_preps=1)
    inputs = {}
    for name, arch in (("mnet", "mobilenet"), ("snet", "squeezenet")):
        layers, x = build_cnn(arch, image=16, width=0.25)
        srv.add_model(name, layers)
        srv.decide(name, x, n_little=2)
        inputs[name] = x
    return srv, inputs


def test_two_models_cold_start_concurrently_no_crosstalk(two_model_server):
    srv, inputs = two_model_server
    isolated = {n: srv.cold_start(n, x).result() for n, x in inputs.items()}
    results = {}

    def go(name):
        results[name] = srv.cold_start(name, inputs[name]).result()

    ts = [threading.Thread(target=go, args=(n,)) for n in inputs]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for name in inputs:
        np.testing.assert_array_equal(np.asarray(results[name].output),
                                      np.asarray(isolated[name].output))
        # traces cover exactly this model's layers — no cross-talk
        assert {t.layer for t in results[name].traces} == \
            {t.layer for t in isolated[name].traces}
        # resident weights belong to the right model
        assert set(results[name].weights) == \
            {l.spec.name for l in srv.engines[name].layers}
    assert srv.stats["max_active_preps"] <= 1


def test_admission_blocks_second_prep(two_model_server):
    """With cap=1, the second cold start must not enter its prep phase
    while the first is still prepping."""
    srv, inputs = two_model_server
    order = []

    def go(name):
        t = srv.cold_start(name, inputs[name])
        order.append(("admitted", name))
        t.result()

    ts = [threading.Thread(target=go, args=(n,)) for n in inputs]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert srv.stats["max_active_preps"] <= 1
    assert len(order) == 2


def test_lru_eviction_under_memory_budget(tmp_path):
    from repro.models.cnn import build_cnn

    srv = ColdServer(tmp_path, n_little=2, max_concurrent_preps=2)
    for name, arch in (("m1", "mobilenet"), ("m2", "squeezenet")):
        layers, x = build_cnn(arch, image=16, width=0.25)
        srv.add_model(name, layers)
        srv.decide(name, x, n_little=2)
        srv.cold_start(name, x).result()
        if name == "m1":
            # budget just under both models: the second arrival must evict
            srv.memory_budget_bytes = srv.resident_bytes() + 1
    assert srv.resident_models() == ["m2"]
    assert srv.stats["evictions"] == 1
    # evicted model serves cold again; resident model serves warm
    layers, x1 = build_cnn("mobilenet", image=16, width=0.25)
    assert srv.warm_run("m1", x1) is None
    r = srv.run("m1", x1)
    assert r.output is not None


def test_shared_profile_db_second_model_zero_profile_calls(tmp_path):
    """Satellite: one user-level ProfileDB for all managed engines — a
    sibling model with the same shape classes performs zero profile
    calls."""
    from repro.core.llm_graph import tiny_llm_graph

    srv = ColdServer(tmp_path, n_little=2)
    g1, toks = tiny_llm_graph(4, seed=0)
    g2, _ = tiny_llm_graph(4, seed=1)     # same shapes, different weights
    srv.add_model("m1", g1)
    srv.add_model("m2", g2)
    s1 = srv.decide("m1", toks, n_little=2)
    s2 = srv.decide("m2", toks, n_little=2)
    assert s1["profile_calls"] > 0
    assert s2["profile_calls"] == 0
    assert s2["profile_db_hits"] > 0
    # both engines share the one DB object at the server root
    assert srv.engines["m1"].profile_db is srv.engines["m2"].profile_db
    assert srv.profile_db.path.parent == srv.root


def test_cold_llm_first_token_before_last_layer_prep(tmp_path):
    """The serving bridge: first token from the streamed prefill precedes
    the last layer's decode-path prep; weight preps overlap the exec
    chain (execute-as-you-load); decode continues via BatchedServer."""
    from repro.configs import get_config
    from repro.core.llm_graph import tiny_llm_graph
    from repro.executor.llm_bridge import cold_start_llm

    cfg = get_config("smollm-360m").reduced(
        num_layers=4, d_model=128, d_ff=256, num_heads=2, num_kv_heads=1,
        head_dim=64, vocab_size=512)
    graph, toks = tiny_llm_graph(4)
    srv = ColdServer(tmp_path, n_little=2)
    eng = srv.add_model("llm", graph)
    srv.decide("llm", toks, n_little=2)
    res = cold_start_llm(eng, cfg, toks[0], max_new_tokens=3, n_little=2,
                         server=srv, model_name="llm")
    assert res.first_token_before_last_prep
    assert res.first_token_s < res.decode_prep_s <= res.decode_ready_s
    assert res.overlapped_layers >= 1
    assert len(res.tokens) == 3
    assert all(0 <= t < cfg.vocab_size for t in res.tokens)
    # the decoded continuation came through the BatchedServer bridge with
    # the packed params: the packed first token matches the streamed one
    assert res.tokens[0] == res.first_token


def test_batched_server_run_until_drained_returns_finished():
    """Regression: run_until_drained used to always return [] — it must
    return the requests that finished during the call."""
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving import BatchedServer, Request

    cfg = get_config("smollm-360m").reduced(num_layers=2, vocab_size=64)
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    srv = BatchedServer(params, cfg, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=5),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done_s is not None for r in done)
    # a second drain with nothing queued returns nothing new
    assert srv.run_until_drained() == []
