"""Config sanity: analytic parameter counts must land near the nominal
model sizes the architecture ids claim; reduced variants stay tiny."""
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config

NOMINAL = {
    "zamba2-2.7b": 2.7e9,
    "granite-moe-3b-a800m": 3.0e9,
    "smollm-360m": 0.36e9,
    "mamba2-2.7b": 2.7e9,
    "qwen3-moe-30b-a3b": 30e9,
    "musicgen-medium": 1.5e9,   # medium ≈ 1.5B
    "mistral-nemo-12b": 12e9,
    "gemma2-27b": 27e9,
    "internvl2-76b": 76e9,      # incl. vision tower; LLM part ≈ 70B
    "qwen3-32b": 32e9,
}

ACTIVE = {
    "granite-moe-3b-a800m": 0.8e9,
    "qwen3-moe-30b-a3b": 3e9,
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_count_near_nominal(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    lo, hi = NOMINAL[arch] * 0.6, NOMINAL[arch] * 1.45
    assert lo <= n <= hi, (arch, f"{n/1e9:.2f}B vs nominal {NOMINAL[arch]/1e9}B")


@pytest.mark.parametrize("arch", list(ACTIVE))
def test_moe_active_params(arch):
    cfg = get_config(arch)
    a = cfg.active_param_count()
    assert ACTIVE[arch] * 0.5 <= a <= ACTIVE[arch] * 1.6, f"{a/1e9:.2f}B"
    assert a < cfg.param_count() * 0.5  # sparsity is real


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_is_small(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 2
    assert r.d_model <= 512
    assert (r.num_experts or 0) <= 4
    assert r.param_count() < 5e6 + r.vocab_size * r.d_model * 2


def test_shapes_registry():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["train_4k"].global_batch == 256
