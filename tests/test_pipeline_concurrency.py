"""Pipelined-runtime concurrency invariants.

The prefetch "stager" threads are joined before ``RunResult`` is built, so
every trace (including theirs) is complete and stable the moment ``run``
returns; staging is idempotent — each layer is staged exactly once even
under work stealing + prefetch races; and the sequential baseline's read
ops pay the real disk cost (``mmap=False``) instead of deferring it into
transform/stage through a lazy mmap view.
"""
import time

import numpy as np
import pytest


@pytest.fixture(scope="module")
def tiny_engine(tmp_path_factory):
    from repro.core.engine import ColdEngine
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    eng = ColdEngine(layers, tmp_path_factory.mktemp("conc_store"))
    eng.decide(x, n_little=2)
    return eng, x


def test_each_layer_staged_exactly_once_across_runs(tiny_engine):
    """Work stealing + i+1 prefetch + deferred staging must never produce a
    duplicate (or missing) 'stage' op for a layer, run after run."""
    eng, x = tiny_engine
    weighted = {l.spec.name for l in eng.layers if l.spec.weight_shapes}
    for _ in range(5):
        rt = eng.make_runtime(n_little=2)
        rt.stage_in_prep = False  # force the deferred/prefetch staging path
        res = rt.run(np.asarray(x, dtype=np.float32), eng.plan)
        counts = {}
        for t in res.traces:
            if t.kind == "stage":
                counts[t.layer] = counts.get(t.layer, 0) + 1
        assert counts == {n: 1 for n in weighted}, counts


def test_traces_complete_when_run_returns(tiny_engine):
    """Stager threads are joined before RunResult is constructed: no trace
    may be appended after ``run`` returns, and every op kind is fully
    accounted for."""
    eng, x = tiny_engine
    rt = eng.make_runtime(n_little=2)
    rt.stage_in_prep = False
    res = rt.run(np.asarray(x, dtype=np.float32), eng.plan)
    n = len(res.traces)
    time.sleep(0.05)
    assert len(res.traces) == n, "a stager appended a trace post-return"
    weighted = {l.spec.name for l in eng.layers if l.spec.weight_shapes}
    by_kind = {}
    for t in res.traces:
        by_kind.setdefault(t.kind, set()).add(t.layer)
    assert by_kind["read"] == weighted
    assert by_kind["stage"] == weighted
    assert by_kind["execute"] == {l.spec.name for l in eng.layers}
    # every stage finished before its layer's execute started
    exec_start = {t.layer: t.start for t in res.traces if t.kind == "execute"}
    for t in res.traces:
        if t.kind == "stage":
            assert t.end <= exec_start[t.layer] + 1e-9


def test_sequential_baseline_reads_materialize(tiny_engine, monkeypatch):
    """run_sequential must read with mmap=False so the baseline's 'read'
    traces carry the real disk cost, not metadata-only mmap setup."""
    eng, x = tiny_engine
    rt = eng.make_runtime(n_little=2)
    calls = []
    real_read = rt.store.read_raw

    def spy(layer, *, mmap=None):
        calls.append(mmap)
        return real_read(layer, mmap=mmap)

    monkeypatch.setattr(rt.store, "read_raw", spy)
    res = rt.run_sequential(np.asarray(x, dtype=np.float32))
    assert calls and all(m is False for m in calls), calls
    read_s = res.stage_seconds().get("read", 0.0)
    assert read_s > 0.0
