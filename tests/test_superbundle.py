"""Model-level super-bundle (v2 container) tests.

Covers: raw+cache round-trips across dtypes (incl. native bf16),
empty-weights layers and dotted layer names, 64-byte alignment and
plan-order sequential layout, in-place cache replace vs rewrite-on-grow,
drop/compaction, migration from per-layer bundles, LayerStore
``fmt="super"`` equivalence with ``fmt="bundle"``, the one-open-per-model
property, readahead hints, and a full ColdEngine run on a super store.
"""
import numpy as np
import pytest

from repro.checkpoint import LayerStore
from repro.checkpoint.bundle import ALIGN
from repro.checkpoint.superbundle import (
    HEADER_SLACK, SuperBundle, compact, drop_cache_entry, migrate,
    read_super_header, set_cache_entry, write_superbundle,
)


def _model_weights():
    import ml_dtypes

    rng = np.random.default_rng(0)
    return {
        "block.0": {
            "w": rng.standard_normal((17, 33)).astype(np.float32),
            "b": rng.standard_normal(33).astype(np.float32),
        },
        "block.1": {
            "hb": rng.standard_normal((12, 8)).astype(np.float32)
                  .astype(ml_dtypes.bfloat16),
            "q8": (rng.standard_normal((5, 9)) * 20).astype(np.int8),
        },
        "empty": {},  # weightless layer: present, no tensors
    }


@pytest.mark.parametrize("materialize", [False, True])
def test_superbundle_roundtrip(tmp_path, materialize):
    import ml_dtypes

    w = _model_weights()
    p = tmp_path / "m.superbundle"
    write_superbundle(p, w, order=list(w))
    with SuperBundle(p) as sb:
        assert sb.order == list(w)
        for layer, tensors in w.items():
            back = sb.read_raw(layer, materialize=materialize)
            assert set(back) == set(tensors)
            for k in tensors:
                assert back[k].dtype == tensors[k].dtype, (layer, k)
                np.testing.assert_array_equal(
                    np.asarray(back[k]), np.asarray(tensors[k]))
        assert sb.read_raw("block.1")["hb"].dtype == ml_dtypes.bfloat16
        assert sb.read_raw("empty") == {}
        assert sb.read_raw("no_such_layer") == {}


def test_superbundle_alignment_and_sequential_layout(tmp_path):
    w = _model_weights()
    p = tmp_path / "m.superbundle"
    write_superbundle(p, w, order=["block.0", "block.1", "empty"])
    hdr = read_super_header(p)
    assert hdr["order"] == ["block.0", "block.1", "empty"]
    offsets = []
    for layer in hdr["order"]:
        for e in hdr["layers"][layer]["raw"]:
            assert e["offset"] % ALIGN == 0
            offsets.append(e["offset"])
    # layers laid out in order -> a cold sweep reads the file front to back
    assert offsets == sorted(offsets)


def test_cache_entry_inplace_vs_rewrite_on_grow(tmp_path):
    w = _model_weights()
    p = tmp_path / "m.superbundle"
    write_superbundle(p, w, order=list(w))
    c1 = {"w": np.zeros((17, 33), np.float32)}
    assert set_cache_entry(p, "block.0", "kA", c1) == "rewrite"  # append grows
    size1 = p.stat().st_size
    c2 = {"w": np.full((17, 33), 3.0, np.float32)}
    assert set_cache_entry(p, "block.0", "kA", c2) == "inplace"  # fits slot
    assert p.stat().st_size == size1
    with SuperBundle(p) as sb:
        np.testing.assert_array_equal(
            np.asarray(sb.read_cached("block.0", "kA")["w"]), c2["w"])
        # neighbors untouched by the in-place write
        np.testing.assert_array_equal(
            np.asarray(sb.read_raw("block.0")["w"]), w["block.0"]["w"])
    c3 = {"w": np.ones((170, 33), np.float32)}
    assert set_cache_entry(p, "block.0", "kA", c3) == "rewrite"  # grew
    with SuperBundle(p) as sb:
        assert sb.read_cached("block.0", "kA")["w"].shape == (170, 33)
        np.testing.assert_array_equal(
            np.asarray(sb.read_raw("block.1")["q8"]), w["block.1"]["q8"])


def test_drop_then_compact_reclaims(tmp_path):
    """Dropping an entry is an O(header) in-place commit that leaves the
    extent dead on disk; ``compact`` reclaims it via the atomic rewrite."""
    w = _model_weights()
    p = tmp_path / "m.superbundle"
    write_superbundle(p, w, order=list(w))
    base = p.stat().st_size
    set_cache_entry(p, "block.0", "kA",
                    {"w": np.ones((64, 64), np.float32)})
    grown = p.stat().st_size
    assert grown > base
    assert drop_cache_entry(p, "block.0", "kA") is True
    assert drop_cache_entry(p, "block.0", "kA") is False
    assert p.stat().st_size == grown  # hole left behind, no rewrite
    with SuperBundle(p) as sb:
        assert not sb.has_cached("block.0", "kA")
        assert sb.reclaimable_bytes() > 0
    stats = compact(p)
    assert stats["reclaimed_bytes"] > 0
    assert p.stat().st_size == base  # compaction reclaimed the dead extent
    with SuperBundle(p) as sb:
        assert not sb.has_cached("block.0", "kA")
        assert sb.reclaimable_bytes() == 0


def test_header_slack_allows_inplace_metadata_change(tmp_path):
    """Shrinking a cache entry (different nbytes digits) must still commit
    in place thanks to the header slack."""
    p = tmp_path / "m.superbundle"
    write_superbundle(p, {"l": {"w": np.zeros(4, np.float32)}}, order=["l"])
    set_cache_entry(p, "l", "k", {"w": np.zeros(1000, np.float32)})
    assert set_cache_entry(p, "l", "k",
                           {"w": np.arange(9, dtype=np.float32)}) == "inplace"
    with SuperBundle(p) as sb:
        got = sb.read_cached("l", "k")["w"]
        np.testing.assert_array_equal(np.asarray(got),
                                      np.arange(9, dtype=np.float32))
    assert HEADER_SLACK >= 64


def test_migrate_per_layer_bundles(tmp_path):
    w = _model_weights()
    src = LayerStore(tmp_path / "perlayer", fmt="bundle")
    for layer, tensors in w.items():
        src.write_raw(layer, tensors)
    src.write_cached("block.0", "kA", {"t": np.ones(7, np.float32)})
    dest = migrate(tmp_path / "perlayer", tmp_path / "m.superbundle",
                   order=["block.0", "block.1", "empty"])
    with SuperBundle(dest) as sb:
        for layer in ("block.0", "block.1"):
            got = sb.read_raw(layer)
            for k, v in w[layer].items():
                assert got[k].dtype == v.dtype
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(v))
        assert sb.has_cached("block.0", "kA")
        np.testing.assert_array_equal(
            np.asarray(sb.read_cached("block.0", "kA")["t"]),
            np.ones(7, np.float32))


def test_layerstore_super_matches_bundle(tmp_path):
    """fmt="super" reads == fmt="bundle" reads on a cnn_zoo model."""
    from repro.models.cnn import build_cnn

    layers, _ = build_cnn("mobilenet", image=24, width=0.35)
    s_sup = LayerStore(tmp_path / "super", fmt="super")
    s_bun = LayerStore(tmp_path / "bundle", fmt="bundle")
    for l in layers:
        if not l.weights:
            continue
        s_sup.write_raw(l.spec.name, l.weights)
        s_bun.write_raw(l.spec.name, l.weights)
    for l in layers:
        if not l.weights:
            continue
        for mmap in (False, True):
            a = s_sup.read_raw(l.spec.name, mmap=mmap)
            b = s_bun.read_raw(l.spec.name, mmap=mmap)
            assert set(a) == set(b)
            for k in a:
                assert a[k].dtype == b[k].dtype
                np.testing.assert_array_equal(np.asarray(a[k]),
                                              np.asarray(b[k]))
    # weightless layers read back as {} in both formats
    assert s_sup.read_raw("stateless_layer") == {}
    assert s_bun.read_raw("stateless_layer") == {}


def test_layerstore_super_one_open_per_model(tmp_path):
    w = _model_weights()
    st = LayerStore(tmp_path, fmt="super")
    for layer, tensors in w.items():
        st.write_raw(layer, tensors)
    st.read_raw("block.0")  # flush + first open
    st.close()
    st.open_count = 0
    for layer in w:
        st.read_raw(layer)
    assert st.open_count == 1
    # views are immutable (zero-copy into the shared read-only mmap)
    v = st.read_raw("block.0")["w"]
    assert not v.flags.writeable
    with pytest.raises(ValueError):
        v[0, 0] = 1.0


def test_layerstore_super_cache_roundtrip_and_drop(tmp_path):
    import ml_dtypes

    st = LayerStore(tmp_path, fmt="super")
    st.write_raw("l0", {"w": np.ones((8, 8), np.float32)})
    wc = {"w": np.ones((8, 8), np.float32).astype(ml_dtypes.bfloat16)}
    st.write_cached("l0", "bf16_cast", wc)
    assert st.has_cached("l0", "bf16_cast")
    back = st.read_cached("l0", "bf16_cast")
    assert back["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(wc["w"]))
    assert st.cache_bytes() > 0
    st.drop_cached("l0", "bf16_cast")
    assert not st.has_cached("l0", "bf16_cast")
    assert st.cache_bytes() == 0
    assert st.model_bytes() > 0
    assert st.raw_bytes("l0") == 8 * 8 * 4


def test_layerstore_super_batches_cache_materialization(tmp_path, monkeypatch):
    """A decide()-style loop materializing caches for many layers must
    coalesce into ONE container rewrite at the next flush point, not one
    rewrite per layer."""
    import repro.checkpoint.io as io_mod

    st = LayerStore(tmp_path, fmt="super")
    for layer, tensors in _model_weights().items():
        st.write_raw(layer, tensors)
    st.read_raw("block.0")  # install flush

    calls = []
    real = io_mod.write_superbundle

    def counting(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(io_mod, "write_superbundle", counting)
    st.write_cached("block.0", "k", {"t": np.ones(3, np.float32)})
    st.write_cached("block.1", "k", {"t": np.full(4, 2.0, np.float32)})
    st.drop_cached("block.1", "k")
    # buffered entries are served (and dropped) without flushing
    np.testing.assert_array_equal(
        np.asarray(st.read_cached("block.0", "k")["t"]),
        np.ones(3, np.float32))
    assert st.read_cached("block.1", "k") == {}
    assert not st.has_cached("block.1", "k")
    assert calls == []
    assert st.cache_bytes() > 0  # flush point
    assert len(calls) == 1
    assert st.has_cached("block.0", "k")
    # model + cache accounting sums to the real on-disk file size
    assert (st.model_bytes() + st.cache_bytes()
            == (tmp_path / "model.superbundle").stat().st_size)


def test_layerstore_super_readahead(tmp_path):
    w = _model_weights()
    st = LayerStore(tmp_path, fmt="super")
    for layer, tensors in w.items():
        st.write_raw(layer, tensors)
    # hints for present, empty, and unknown layers must all be safe
    hinted = st.readahead(["block.0", "block.1", "empty", "nope"])
    assert 0 <= hinted <= 2
    assert LayerStore(tmp_path / "b", fmt="bundle").readahead(["x"]) == 0


def test_cold_engine_on_super_store(tmp_path):
    """Full decide() + run_cold() through a super-bundle store matches the
    per-layer bundle store's output."""
    from repro.core.engine import ColdEngine
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    eng_b = ColdEngine(layers, tmp_path / "bundle", store_fmt="bundle")
    eng_b.decide(x, n_little=2)
    out_b = np.asarray(eng_b.run_cold(x).output)

    eng_s = ColdEngine(layers, tmp_path / "super", store_fmt="super")
    stats = eng_s.decide(x, n_little=2)
    res = eng_s.run_cold(x)
    np.testing.assert_allclose(np.asarray(res.output), out_b,
                               rtol=2e-4, atol=2e-5)
    assert (tmp_path / "super" / "model.superbundle").exists()
    assert stats["model_bytes"] > 0
    # the sequential baseline works against the same single-file store
    out_seq = np.asarray(eng_s.run_cold(x, mode="sequential").output)
    np.testing.assert_allclose(out_seq, out_b, rtol=2e-4, atol=2e-5)
