"""Fault-domain layer (PR 6): typed taxonomy, deterministic injection,
pool-level bounded retries, deadlines + watchdog lane quarantine, and the
typed shutdown-leak detection that replaced the silent ``join(timeout)``.
"""
import errno
import json
import threading
import time

import pytest

from repro.executor.graph import TaskGraph
from repro.executor.pool import CorePool
from repro.faults import (
    CircuitBreaker, DeadlineExceeded, Fault, FaultInjector, IntegrityFault,
    JobTimeout, KernelFault, PermanentFault, ReadFault, RepairLog,
    RetryPolicy, StageFault, TransientFault, WorkerLost, classify,
    is_transient,
)


# ---------------------------------------------------------------------------
# taxonomy + classification
# ---------------------------------------------------------------------------
def test_taxonomy_shape():
    f = ReadFault("disk hiccup", layer="l0", site="store.read_raw")
    assert isinstance(f, TransientFault) and isinstance(f, Fault)
    assert f.describe()["layer"] == "l0"
    assert f.describe()["site"] == "store.read_raw"
    assert isinstance(KernelFault(""), PermanentFault)
    assert isinstance(IntegrityFault(""), PermanentFault)
    # JobTimeout stays catchable as the stdlib TimeoutError
    assert issubclass(JobTimeout, TimeoutError)
    assert issubclass(JobTimeout, TransientFault)
    assert is_transient(ReadFault("")) and not is_transient(KernelFault(""))


def test_classify_maps_transient_errnos_and_passes_the_rest():
    c = classify(OSError(errno.EIO, "I/O error"),
                 site="store.read_raw", layer="l1")
    assert isinstance(c, ReadFault) and c.layer == "l1"
    # non-transient errno: not our failure mode, pass through untyped
    e = OSError(errno.ENOENT, "missing")
    assert classify(e) is e
    # already-typed faults and unknown exceptions pass through unchanged
    rf = ReadFault("typed")
    assert classify(rf) is rf
    v = ValueError("not io")
    assert classify(v) is v


def test_retry_policy_backoff_schedule():
    r = RetryPolicy(max_attempts=3, backoff_s=0.005, backoff_mult=2.0)
    assert r.delay(1) == pytest.approx(0.005)
    assert r.delay(2) == pytest.approx(0.010)
    assert r.delay(3) == pytest.approx(0.020)


# ---------------------------------------------------------------------------
# deterministic injection
# ---------------------------------------------------------------------------
def test_injector_deterministic_regardless_of_call_order():
    """The fault decision is a pure function of (seed, site, key, call#):
    thread interleaving — modeled here as shuffled key order — must not
    change which calls fault."""
    def run(order):
        inj = FaultInjector(seed=5, rates={"task.read": 0.3},
                            max_faults_per_key=2)
        out = {}
        for key in order:
            hits = 0
            for _ in range(10):
                try:
                    inj.maybe_fault("task.read", key)
                except TransientFault:
                    hits += 1
            out[key] = hits
        return out

    keys = [f"k{i}" for i in range(24)]
    a, b = run(keys), run(list(reversed(keys)))
    assert a == b
    assert sum(a.values()) >= 1, "rate 0.3 over 24 keys must inject"
    # the per-key cap guarantees convergence under bounded retries
    assert all(v <= 2 for v in a.values())


def test_injector_site_classes_and_key_filter():
    inj = FaultInjector(seed=0, rates={"kernel.execute": 1.0},
                        keys={"kernel.execute": {"conv1"}},
                        max_faults_per_key=10)
    with pytest.raises(KernelFault):
        inj.maybe_fault("kernel.execute", "conv1")
    inj.maybe_fault("kernel.execute", "other")  # filtered out: no fault
    assert inj.n_injected == 1
    with pytest.raises(StageFault):
        FaultInjector(seed=0, rates={"task.stage": 1.0}).maybe_fault(
            "task.stage", "x")


# ---------------------------------------------------------------------------
# pool retries
# ---------------------------------------------------------------------------
@pytest.fixture()
def pool():
    p = CorePool(n_big=1, n_little=2, name="faults-test")
    yield p
    p.shutdown()


def test_pool_retries_transient_fault_to_success(pool):
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] <= 2:
            raise ReadFault("transient", layer="a")

    g = TaskGraph()
    g.add("a", "read", affinity="little", lane=0, fn=flaky)
    job = pool.submit(g, name="flaky",
                      retry=RetryPolicy(max_attempts=3, backoff_s=0.001))
    job.wait(10)
    assert attempts["n"] == 3
    assert job.retries == 2
    assert pool.health["task_retries"] >= 2
    assert [e["action"] for e in job.fault_events] == ["retry", "retry"]
    # exactly one trace for the task that finally succeeded
    assert [t.layer for t in job.traces] == ["a"]


def test_pool_retry_exhaustion_raises_typed_fault_and_frees_slot(pool):
    def always():
        raise ReadFault("disk sick")

    g = TaskGraph()
    g.add("a", "read", affinity="little", lane=0, fn=always)
    fired = []
    job = pool.submit(g, name="doomed",
                      retry=RetryPolicy(max_attempts=2, backoff_s=0.001))
    job.add_preps_callback(lambda j: fired.append(1))
    with pytest.raises(ReadFault):
        job.wait(10)
    assert job.retries == 1  # bounded: initial + 1 retry, then fail
    assert pool.health["jobs_failed"] >= 1
    deadline = time.time() + 2.0
    while not fired and time.time() < deadline:
        time.sleep(0.005)
    assert fired, "preps-done (admission slot release) must fire on failure"


def test_permanent_fault_is_not_retried(pool):
    calls = {"n": 0}

    def perm():
        calls["n"] += 1
        raise IntegrityFault("bit rot")

    g = TaskGraph()
    g.add("a", "read", affinity="little", lane=0, fn=perm)
    job = pool.submit(g, name="perm")
    with pytest.raises(IntegrityFault):
        job.wait(10)
    assert calls["n"] == 1 and job.retries == 0


def test_job_wait_timeout_is_typed(pool):
    g = TaskGraph()
    g.add("a", "read", affinity="little", lane=0,
          fn=lambda: time.sleep(0.4))
    job = pool.submit(g, name="slow")
    with pytest.raises(JobTimeout):
        job.wait(0.02)
    with pytest.raises(TimeoutError):  # stdlib-compatible
        job.wait(0.02)
    job.wait(10)  # then completes normally


# ---------------------------------------------------------------------------
# shutdown leak detection (the silent `join(timeout)` regression)
# ---------------------------------------------------------------------------
def test_shutdown_detects_and_reports_leaked_workers():
    pool = CorePool(n_big=1, n_little=1, name="leaky")
    release = threading.Event()
    g = TaskGraph()
    g.add("a", "read", affinity="little", lane=0,
          fn=lambda: release.wait(8.0))
    pool.submit(g, name="hung")
    time.sleep(0.1)  # let the worker enter the hung task
    report = pool.shutdown(timeout=0.2)
    assert report["leaked"], "hung worker must be DETECTED, not ignored"
    assert isinstance(report["error"], WorkerLost)
    assert pool.health["workers_lost"] == len(report["leaked"])
    assert pool.leak_report is report
    release.set()


def test_shutdown_raise_on_leak():
    pool = CorePool(n_big=1, n_little=1, name="leaky2")
    release = threading.Event()
    g = TaskGraph()
    g.add("a", "read", affinity="little", lane=0,
          fn=lambda: release.wait(8.0))
    pool.submit(g, name="hung2")
    time.sleep(0.1)
    with pytest.raises(WorkerLost):
        pool.shutdown(timeout=0.2, raise_on_leak=True)
    release.set()


def test_clean_shutdown_reports_no_leak():
    pool = CorePool(n_big=1, n_little=1, name="clean")
    pool.submit(TaskGraph(), name="empty").wait(5)
    assert pool.shutdown()["leaked"] == []
    assert pool.health["workers_lost"] == 0


# ---------------------------------------------------------------------------
# deadlines + watchdog quarantine
# ---------------------------------------------------------------------------
def test_watchdog_quarantines_hung_lane_and_job_completes():
    pool = CorePool(n_big=1, n_little=2, name="wd",
                    watchdog_interval_s=0.01)
    try:
        hung_once = {"done": False}

        def sticky():
            if not hung_once["done"]:
                hung_once["done"] = True
                time.sleep(1.0)  # first attempt blows the 0.1s deadline

        g = TaskGraph()
        g.add("a", "read", affinity="little", lane=0, fn=sticky, cost=1.0)
        g.add("b", "read", affinity="little", lane=0, fn=lambda: None,
              cost=1.0)
        g.add("c", "read", affinity="little", lane=1, fn=lambda: None,
              cost=1.0)
        job = pool.submit(g, name="hung-lane", deadline_s=0.1)
        job.wait(10)  # completes: the chain was rescheduled off the lane
        assert pool.health["deadline_expired"] >= 1
        assert pool.health["lanes_quarantined"] >= 1
        assert pool.health["workers_replaced"] >= 1
        assert {t.layer for t in job.traces} == {"a", "b", "c"}
    finally:
        pool.shutdown()


def test_execute_deadline_fails_job_typed():
    """An overdue EXECUTE cannot be quarantined away (the exec chain is
    strictly ordered on the big cores) — it fails the job with a typed
    DeadlineExceeded instead of hanging the caller."""
    pool = CorePool(n_big=1, n_little=1, name="exdl",
                    watchdog_interval_s=0.01)
    try:
        g = TaskGraph()
        t = g.add("a", "execute", affinity="big",
                  fn=lambda: time.sleep(0.6))
        t.deadline_s = 0.05  # per-task deadline overrides the job default
        job = pool.submit(g, name="stuck-exec")
        with pytest.raises(DeadlineExceeded):
            job.wait(10)
        assert pool.health["deadline_expired"] >= 1
    finally:
        pool.shutdown()


# ---------------------------------------------------------------------------
# circuit breaker + repair log
# ---------------------------------------------------------------------------
def test_circuit_breaker_threshold_persistence_reset(tmp_path):
    p = tmp_path / "breakers.json"
    br = CircuitBreaker(p, threshold=2)
    key = CircuitBreaker.key("im2col", "sc0")
    assert br.allow(key)
    assert not br.record_failure(key, reason="nan")  # below threshold
    assert br.allow(key)
    assert br.record_failure(key, reason="nan")      # opens now
    assert not br.allow(key)
    br2 = CircuitBreaker(p, threshold=2)             # persisted
    assert not br2.allow(key) and br2.open_keys() == [key]
    br2.record_success(key)
    assert br2.allow(key)
    br2.record_failure(key)
    br2.record_failure(key)
    br2.reset()
    assert CircuitBreaker(p, threshold=2).allow(key)


def test_repair_log_records_and_journals(tmp_path):
    log = RepairLog(tmp_path / "repairs.jsonl")
    log.record("cache_recompute", layer="a", kernel="k", reason="crc")
    log.record("kernel_demoted", layer="b")
    assert [e["layer"] for e in log.of_kind("cache_recompute")] == ["a"]
    assert log.counts() == {"cache_recompute": 1, "kernel_demoted": 1}
    lines = (tmp_path / "repairs.jsonl").read_text().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[0])["kind"] == "cache_recompute"
    # advisory: an unwritable path must never fail the caller
    RepairLog(tmp_path / "no" / "such" / "dir" / "r.jsonl").record("x")


def test_repair_log_rotates_at_size_cap(tmp_path):
    p = tmp_path / "repairs.jsonl"
    log = RepairLog(p, max_bytes=400, retention=2)
    for i in range(60):
        log.record("evt", idx=i, pad="x" * 40)
    assert log.rotations >= 2
    log.record("evt", idx=60)   # reopen the current generation
    # current file restarted small; exactly `retention` old generations
    assert p.stat().st_size <= 400 + 100
    assert p.with_name("repairs.jsonl.1").exists()
    assert p.with_name("repairs.jsonl.2").exists()
    assert not p.with_name("repairs.jsonl.3").exists()
    # every rotated line is still valid jsonl
    for gen in ("", ".1", ".2"):
        for line in p.with_name("repairs.jsonl" + gen).read_text() \
                .splitlines():
            assert json.loads(line)["kind"] == "evt"


def test_repair_log_caps_in_memory_events(tmp_path):
    log = RepairLog(tmp_path / "r.jsonl", max_events=5)
    for i in range(12):
        log.record("evt", idx=i)
    assert len(log.events) == 5
    # oldest evicted, newest kept
    assert [e["idx"] for e in log.events] == list(range(7, 12))
    assert log.counts() == {"evt": 5}
