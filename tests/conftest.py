import os
import signal
import sys
import threading

import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the 512-device mesh is dryrun.py-only).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Fault/chaos suites exercise supervision, watchdogs, crash failover, and
# multi-process RPC — exactly the code whose failure mode is a HANG, not an
# assertion. Each test in these modules runs under a wall-clock guard so a
# deadlocked heartbeat/drain/failover path fails loudly instead of stalling
# the whole run (CI's job-level timeout would otherwise eat the evidence of
# WHICH test hung).
_GUARDED_MODULES = {
    "test_faults", "test_crash_recovery", "test_degradation",
    "test_frontdoor", "test_deadlines", "test_cold_server", "test_drift",
    "test_warmstate",
}
_PER_TEST_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT_S", "180"))


@pytest.fixture
def restore_flags():
    """Snapshot/restore the runtime feature-flag dict around a test.

    Any test that flips ``repro.models.runtime_flags.FLAGS`` (kv-cache
    quantization, lossy kernel gates, ...) should depend on this fixture so
    mutations never leak into later tests."""
    from repro.models.runtime_flags import FLAGS

    old = dict(FLAGS)
    yield FLAGS
    FLAGS.clear()
    FLAGS.update(old)


@pytest.fixture(autouse=True)
def _fault_chaos_timeout_guard(request):
    mod = getattr(request.node.module, "__name__", "")
    if (mod.rpartition(".")[2] not in _GUARDED_MODULES
            or not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        yield  # clean no-op off-POSIX / off-main-thread
        return

    def _expire(signum, frame):
        raise TimeoutError(
            f"{request.node.nodeid} exceeded the {_PER_TEST_TIMEOUT_S:.0f}s "
            f"per-test guard for fault/chaos modules — likely a hung "
            f"drain/heartbeat/failover path")

    old_handler = signal.signal(signal.SIGALRM, _expire)
    signal.setitimer(signal.ITIMER_REAL, _PER_TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old_handler)
