"""Cold-inference engine end-to-end + component tests."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.engine import ColdEngine
from repro.core.registry import (
    ConvDirect, ConvIm2col, ConvWinograd, LayerSpec, LinearDirect,
    LinearPacked,
)
from repro.models.cnn import build_cnn


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    layers, x = build_cnn("mobilenet", image=24, width=0.35)
    eng = ColdEngine(layers, tmp_path_factory.mktemp("store"))
    stats = eng.decide(x, n_little=2)
    return eng, x, stats


def test_decide_produces_plan(engine):
    eng, x, stats = engine
    assert eng.plan is not None
    assert stats["plan_generation_s"] > 0
    assert stats["est_makespan_s"] > 0
    # every layer got a choice
    assert len(eng.plan.choices) == len(eng.layers)


def test_cold_modes_agree(engine):
    eng, x, _ = engine
    r1 = eng.run_cold(x, mode="nnv12")
    r2 = eng.run_cold(x, mode="sequential")
    np.testing.assert_allclose(np.asarray(r1.output), np.asarray(r2.output),
                               atol=1e-4, rtol=1e-4)


def test_warm_faster_than_cold_sequential(engine):
    eng, x, _ = engine
    warm = eng.run_warm(x)
    r2 = eng.run_cold(x, mode="sequential")
    assert warm < r2.total_s


def test_cache_storage_accounted(engine):
    eng, x, stats = engine
    cached = [c for c in eng.plan.choices if c.use_cache]
    if cached:
        assert stats["cache_bytes"] > 0
    assert stats["model_bytes"] > 0


def test_plan_roundtrip(engine):
    from repro.core.scheduler import Plan

    eng, _, _ = engine
    d = eng.plan.to_dict()
    p2 = Plan.from_dict(d)
    assert p2.to_dict() == d


def test_kernel_equivalence_conv():
    rng = np.random.default_rng(0)
    spec = LayerSpec("c", "conv2d",
                     {"kernel": 3, "stride": 1, "padding": "SAME"},
                     {"w": (12, 6, 3, 3), "b": (12,)})
    raw = {"w": rng.standard_normal((12, 6, 3, 3)).astype(np.float32),
           "b": rng.standard_normal(12).astype(np.float32)}
    x = jnp.asarray(rng.standard_normal((2, 9, 9, 6)).astype(np.float32))
    outs = []
    for K in (ConvDirect(), ConvIm2col(), ConvWinograd()):
        w = {k: jnp.asarray(v) for k, v in K.transform(raw, spec).items()}
        outs.append(np.asarray(K.execute(w, x, spec)))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


def test_kernel_equivalence_linear():
    rng = np.random.default_rng(1)
    spec = LayerSpec("l", "linear",
                     {"in_features": 70, "out_features": 33},
                     {"w": (70, 33)})
    raw = {"w": rng.standard_normal((70, 33)).astype(np.float32)}
    x = jnp.asarray(rng.standard_normal((4, 70)).astype(np.float32))
    y0 = LinearDirect().execute(
        {k: jnp.asarray(v) for k, v in raw.items()}, x, spec)
    lp = LinearPacked()
    y1 = lp.execute({k: jnp.asarray(v)
                     for k, v in lp.transform(raw, spec).items()}, x, spec)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)


def test_winograd_transform_size_tradeoff():
    """Table 2's premise: winograd's transformed weights are larger than raw
    (16/9 per filter) and its transform is the expensive stage."""
    spec = LayerSpec("c", "conv2d",
                     {"kernel": 3, "stride": 1, "padding": "SAME"},
                     {"w": (32, 16, 3, 3)})
    rng = np.random.default_rng(0)
    raw = {"w": rng.standard_normal((32, 16, 3, 3)).astype(np.float32)}
    wino = ConvWinograd().transform(raw, spec)
    raw_b = sum(v.nbytes for v in raw.values())
    wino_b = sum(v.nbytes for v in wino.values())
    assert wino_b > raw_b * 1.5  # 16/9 ≈ 1.78x


def test_continuous_session_switching(tmp_path):
    from repro.core.switching import ContinuousSession

    layers, x = build_cnn("squeezenet", image=24, width=0.35)
    eng = ColdEngine(layers, tmp_path)
    eng.decide(x, n_little=2)
    sess = ContinuousSession(eng, n_little=2)
    r1 = sess.cold_infer(x)
    r2 = sess.warm_infer(x, wait=True)
    np.testing.assert_allclose(np.asarray(r1.output), np.asarray(r2.output),
                               atol=1e-4, rtol=1e-4)


def test_io_interference_measured(engine):
    """§3.2: the engine calibrates co-read interference; factor is >= 1 and
    folded into the plan's little-core prep costs."""
    eng, x, stats = engine
    assert stats["io_interference"] >= 1.0
    assert eng.io_interference == stats["io_interference"]
