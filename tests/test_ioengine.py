"""Async I/O engine (PR 7): backend probe/self-check, pinned buffer pool,
byte-budget admission, depth planning, extent-granular store reads, fault
injection at the new engine sites, and the async/sync bit-identity the
whole refactor is gated on.
"""
import os
import threading
import time

import numpy as np
import pytest

import repro.ioengine as iomod
from repro.checkpoint import LayerStore
from repro.faults import FaultInjector, ReadFault, RetryPolicy
from repro.ioengine import (
    IOEngine, PinnedBufferPool, StageEngine, available_backends,
    get_io_engine, reset_io_engine, reset_stage_engine,
)


@pytest.fixture(autouse=True)
def _fresh_singletons():
    reset_io_engine()
    reset_stage_engine()
    yield
    reset_io_engine()
    reset_stage_engine()


def _write_file(path, nbytes, seed=7):
    data = (np.arange(nbytes, dtype=np.int64) * seed % 251).astype(np.uint8)
    path.write_bytes(data.tobytes())
    return data


# ---------------------------------------------------------------------------
# backend probe / self-check / override
# ---------------------------------------------------------------------------
def test_probe_always_lands_on_a_backend():
    eng = IOEngine()
    try:
        assert eng.name in ("uring", "aio", "sync")
    finally:
        eng.close()


def test_available_backends_include_portable_floor():
    avail = available_backends()
    # aio (thread pool over preadv) and sync are pure-python portable
    assert "aio" in avail and "sync" in avail


def test_env_override_forces_backend(monkeypatch):
    monkeypatch.setenv("REPRO_IO_ENGINE", "sync")
    eng = IOEngine()
    try:
        assert eng.name == "sync"
    finally:
        eng.close()


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        IOEngine(backend="nvme-of")


def test_singleton_reset(tmp_path):
    a = get_io_engine()
    assert get_io_engine() is a
    reset_io_engine()
    b = get_io_engine()
    assert b is not a


# ---------------------------------------------------------------------------
# reads: correctness + cross-backend bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", available_backends())
def test_reads_bit_identical_to_file(tmp_path, backend):
    data = _write_file(tmp_path / "blob", 300_000)
    eng = IOEngine(backend=backend)
    fd = os.open(tmp_path / "blob", os.O_RDONLY)
    try:
        cases = [(0, 4096), (4096, 65536), (100_003, 31_337), (0, 300_000)]
        tickets = [eng.submit(fd, off, n, key=f"c{i}")
                   for i, (off, n) in enumerate(cases)]
        for (off, n), t in zip(cases, tickets):
            view = t.wait(5.0)
            assert not view.flags.writeable  # staging contract
            assert np.array_equal(view, data[off:off + n])
            t.release()
        snap = eng.snapshot()
        assert snap["in_flight"] == 0 and snap["bytes_in_flight"] == 0
        assert snap["reaped"] == len(cases)
    finally:
        os.close(fd)
        eng.close()


def test_short_file_read_is_an_error(tmp_path):
    _write_file(tmp_path / "blob", 1000)
    eng = IOEngine(backend="aio")
    fd = os.open(tmp_path / "blob", os.O_RDONLY)
    try:
        t = eng.submit(fd, 512, 4096, key="short")
        with pytest.raises(Exception):
            t.wait(5.0)
    finally:
        os.close(fd)
        eng.close()


# ---------------------------------------------------------------------------
# pinned buffer pool
# ---------------------------------------------------------------------------
def test_pool_recycles_size_classes():
    pool = PinnedBufferPool(max_bytes=1 << 20, pin=False)
    a = pool.acquire(5000)
    cap = a.capacity
    pool._release(a)
    b = pool.acquire(6000)   # same power-of-2 class -> recycled slab
    assert b.capacity == cap and pool.stats["reuses"] == 1
    pool._release(b)
    pool.close()


def test_pool_release_is_idempotent():
    pool = PinnedBufferPool(max_bytes=1 << 20, pin=False)
    a = pool.acquire(4096)
    a.release()
    a.release()  # double release must not double-free the slab
    x = pool.acquire(4096)
    y = pool.acquire(4096)
    assert x.arr is not y.arr
    pool.close()


def test_pool_overflow_allocs_beyond_budget_are_unpooled():
    pool = PinnedBufferPool(max_bytes=8192, pin=False)
    big = pool.acquire(1 << 20)
    assert pool.stats["overflow_allocs"] == 1
    big.release()
    assert pool.stats["retained_bytes"] <= 8192
    pool.close()


# ---------------------------------------------------------------------------
# byte-budget admission
# ---------------------------------------------------------------------------
def test_byte_budget_blocks_submit_until_completion(tmp_path):
    _write_file(tmp_path / "blob", 1 << 20)
    eng = IOEngine(backend="aio", max_bytes_in_flight=256 * 1024)
    fd = os.open(tmp_path / "blob", os.O_RDONLY)
    try:
        tickets = [eng.submit(fd, 0, 200 * 1024, key=f"k{i}")
                   for i in range(4)]  # forces budget waits past the first
        for t in tickets:
            assert np.asarray(t.wait(10.0)).nbytes == 200 * 1024
            t.release()
        assert eng.snapshot()["budget_waits"] >= 1
        assert eng.bytes_in_flight() == 0
    finally:
        os.close(fd)
        eng.close()


def test_oversized_request_admitted_alone_no_wedge(tmp_path):
    _write_file(tmp_path / "blob", 1 << 20)
    eng = IOEngine(backend="aio", max_bytes_in_flight=64 * 1024)
    fd = os.open(tmp_path / "blob", os.O_RDONLY)
    try:
        t = eng.submit(fd, 0, 1 << 20, key="huge")  # > whole budget
        assert np.asarray(t.wait(10.0)).nbytes == 1 << 20
        t.release()
    finally:
        os.close(fd)
        eng.close()


def test_idle_callback_fires_on_drain(tmp_path):
    _write_file(tmp_path / "blob", 65536)
    eng = IOEngine(backend="aio")
    fired = threading.Event()
    eng.add_idle_callback(fired.set)
    fd = os.open(tmp_path / "blob", os.O_RDONLY)
    try:
        t = eng.submit(fd, 0, 65536, key="k")
        t.wait(5.0)
        t.release()
        assert fired.wait(5.0)
    finally:
        os.close(fd)
        eng.close()


# ---------------------------------------------------------------------------
# depth planning (scheduler knob -> graph metadata)
# ---------------------------------------------------------------------------
def test_plan_read_depth_scales_with_read_share():
    from repro.core.scheduler import plan_read_depth

    # read-dominated prep: deep queue
    assert plan_read_depth([1.0] * 8, [0.1] * 8) == 8
    # transform/stage-dominated: shallow
    assert plan_read_depth([0.1] * 8, [1.0] * 8) == 1
    # no reads at all: depth 1
    assert plan_read_depth([], [1.0]) == 1
    # interference scales the read column up
    d1 = plan_read_depth([0.5] * 4, [1.0] * 4, io_interference=1.0)
    d2 = plan_read_depth([0.5] * 4, [1.0] * 4, io_interference=3.0)
    assert d2 >= d1
    # clamp
    assert plan_read_depth([100.0], [0.001], max_depth=4) == 4


def test_plan_read_depth_roundtrips_through_json():
    from repro.core.scheduler import Choice, Plan

    p = Plan([Choice("k", False)], [0], [], 0.0, read_depth=5)
    q = Plan.from_dict(p.to_dict())
    assert q.read_depth == 5
    # pre-PR plan.json (no read_depth key) loads at the sync-era default
    d = p.to_dict()
    del d["read_depth"]
    assert Plan.from_dict(d).read_depth == 1


def test_compile_plan_stamps_depth_on_read_tasks():
    from repro.core.scheduler import Choice, Plan
    from repro.executor.graph import compile_plan

    order = ["a", "b", "c"]
    plan = Plan([Choice("k", False)] * 3, [0], [[1], [2]], 0.0,
                read_depth=6)
    g = compile_plan(order, plan, weighted={n: True for n in order},
                     use_cache={n: False for n in order})
    for t in g.tasks:
        if t.kind == "read":
            assert t.depth == 6
        else:
            assert t.depth == 1
    # explicit override wins over the plan's
    g2 = compile_plan(order, plan, weighted={n: True for n in order},
                      use_cache={n: False for n in order}, read_depth=2)
    assert all(t.depth == 2 for t in g2.tasks if t.kind == "read")


# ---------------------------------------------------------------------------
# store-level extent reads (super + bundle), CRC drop ladder
# ---------------------------------------------------------------------------
def _store_with_layers(tmp_path, fmt):
    store = LayerStore(tmp_path / fmt, fmt=fmt)
    rng = np.random.default_rng(0)
    want = {}
    for i in range(4):
        w = {"w": rng.standard_normal((64, 64)).astype(np.float32),
             "b": rng.standard_normal((64,)).astype(np.float32)}
        store.write_raw(f"l{i}", w)
        want[f"l{i}"] = w
    if fmt == "super":
        store._super(flush_all=True)
    return store, want


@pytest.mark.parametrize("fmt", ["super", "bundle"])
@pytest.mark.parametrize("backend", available_backends())
def test_submit_read_raw_matches_sync(tmp_path, fmt, backend):
    store, want = _store_with_layers(tmp_path, fmt)
    assert store.supports_async
    eng = IOEngine(backend=backend)
    try:
        handles = {n: store.submit_read_raw(eng, n) for n in want}
        for n, w in want.items():
            got = handles[n].wait(10.0)
            for k, v in w.items():
                assert np.array_equal(np.asarray(got[k]), v), (n, k)
            handles[n].release()
    finally:
        eng.close()
        store.close()


def test_npy_store_stays_sync(tmp_path):
    store, want = _store_with_layers(tmp_path, "npy")
    assert not store.supports_async
    eng = IOEngine(backend="sync")
    try:
        h = store.submit_read_raw(eng, "l0")   # immediate-read shim
        got = h.wait()
        assert np.array_equal(np.asarray(got["w"]), want["l0"]["w"])
    finally:
        eng.close()


def test_async_corrupt_cache_extent_drops_and_reports(tmp_path):
    from repro.checkpoint.superbundle import read_super_header

    store, want = _store_with_layers(tmp_path, "super")
    store.write_cached("l0", "k", {"w": np.ones((8, 8), np.float32)})
    store._super(flush_all=True)
    store.close()
    ent = read_super_header(store._super_path)["layers"]["l0"]["cache"]["k"][0]
    with open(store._super_path, "r+b") as f:
        f.seek(ent["offset"] + 5)
        f.write(b"\xff\xff\xff")
    eng = IOEngine(backend="aio")
    try:
        h = store.submit_read_cached(eng, "l0", "k")
        assert h.wait(10.0) == {}  # dropped, like the sync audit
        assert any(d.get("layer") == "l0"
                   and "checksum" in d.get("reason", "")
                   for d in store.dropped_entries)
        # raw side of the same layer still reads clean
        h2 = store.submit_read_raw(eng, "l0")
        got = h2.wait(10.0)
        assert np.array_equal(np.asarray(got["w"]), want["l0"]["w"])
        h2.release()
    finally:
        eng.close()
        store.close()


# ---------------------------------------------------------------------------
# fault injection at the engine sites: bounded retries, typed faults,
# nothing leaked at shutdown
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("site", ["ioengine.submit", "ioengine.reap"])
def test_injected_engine_fault_is_typed_and_retryable(tmp_path, site):
    store, want = _store_with_layers(tmp_path, "super")
    inj = FaultInjector(seed=3, rates={site: 1.0}, max_faults_per_key=1)
    store.fault_injector = inj
    eng = IOEngine(backend="aio")
    try:
        # per-extent keys each fault at most once (max_faults_per_key=1),
        # so a bounded number of retries always clears the chaos — the
        # same guarantee the pool's RetryPolicy leans on. The executor's
        # read task retries the whole submit+wait op, so the test does too.
        got, faults, h = None, 0, None
        for _ in range(6):
            try:
                if h is None:
                    h = store.submit_read_raw(eng, "l0")
                got = h.wait(10.0)
                break
            except ReadFault:
                faults += 1   # handle self-reset: next attempt resubmits
        assert got is not None and faults >= 1
        for k, v in want["l0"].items():
            assert np.array_equal(np.asarray(got[k]), v)
        h.release()
        assert inj.injected and inj.injected[0]["site"] == site
        snap = eng.snapshot()
        assert snap["in_flight"] == 0 and snap["bytes_in_flight"] == 0
    finally:
        store.fault_injector = None
        eng.close()
        store.close()


def test_cold_run_survives_engine_site_chaos(tmp_path):
    """End-to-end: chaos at both engine sites, pool-level bounded retries
    clear every injected fault, output bit-identical to the quiet run."""
    from repro.core.engine import ColdEngine
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    eng = ColdEngine(layers, tmp_path / "s", store_fmt="super",
                     shader_cache=False)
    eng.decide(x, n_little=2)
    y0 = np.asarray(eng.run_cold(x, n_little=2).output)
    inj = FaultInjector(seed=11, rates={"ioengine.submit": 0.3,
                                        "ioengine.reap": 0.3},
                        max_faults_per_key=1)
    eng.fault_injector = inj
    eng.store.fault_injector = inj
    eng.retry_policy = RetryPolicy(max_attempts=4, backoff_s=0.0)
    eng._runtimes.clear()
    try:
        y1 = np.asarray(eng.run_cold(x, n_little=2).output)
    finally:
        eng.fault_injector = None
        eng.store.fault_injector = None
    assert inj.injected, "chaos must actually fire to prove anything"
    np.testing.assert_array_equal(y0, y1)
    io_eng = get_io_engine()
    snap = io_eng.snapshot()
    assert snap["in_flight"] == 0 and snap["bytes_in_flight"] == 0


def test_engine_close_leaks_nothing(tmp_path):
    _write_file(tmp_path / "blob", 65536)
    before = {t.name for t in threading.enumerate()}
    eng = IOEngine(backend="aio")
    fd = os.open(tmp_path / "blob", os.O_RDONLY)
    t = eng.submit(fd, 0, 65536, key="k")
    t.wait(5.0)
    t.release()
    os.close(fd)
    eng.close()
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        after = {t.name for t in threading.enumerate()} - before
        if not any(n.startswith("repro-") for n in after):
            break
        time.sleep(0.05)
    leaked = [n for n in ({t.name for t in threading.enumerate()} - before)
              if n.startswith("repro-")]
    assert not leaked, f"engine threads leaked past close(): {leaked}"


# ---------------------------------------------------------------------------
# async reads racing a crashing compaction
# ---------------------------------------------------------------------------
def test_async_reads_race_crashing_commit_then_compaction(tmp_path):
    """Reads in flight against the container keep serving correct bytes
    while a journaled cache commit crashes mid-slot-write (torn bytes on
    disk); recovery rolls the tear back, a real compaction then moves
    every live extent, and the next async sweep is still byte-identical."""
    import repro.checkpoint.superbundle as sbmod
    from repro.checkpoint.superbundle import InjectedCrash, set_cache_entry

    store, want = _store_with_layers(tmp_path, "super")
    store.write_cached("l1", "k", {"w": np.ones((32, 32), np.float32)})
    store._super(flush_all=True)
    eng = IOEngine(backend="aio")
    try:
        pend = {n: store.submit_read_raw(eng, n) for n in want}

        def hook(phase, **ctx):
            if phase != "slot":
                return
            f, off = ctx["file"], ctx["offset"]
            payload = ctx["payload"]
            f.seek(off)
            f.write(payload[: len(payload) // 2])   # torn slot write
            f.flush()
            raise InjectedCrash(phase)

        store.close()   # release the reader; commits mutate in place
        sbmod._crash_hook = hook
        try:
            with pytest.raises(InjectedCrash):
                set_cache_entry(store._super_path, "l1", "k",
                                {"w": np.full((32, 32), 0.5, np.float32)})
        finally:
            sbmod._crash_hook = None
        # in-flight reads against the old fd still reap clean bytes
        for n, w in want.items():
            got = pend[n].wait(10.0)
            for k, v in w.items():
                assert np.array_equal(np.asarray(got[k]), v), (n, k)
            pend[n].release()
        # recovery (reopen) drops the torn commit; compaction relocates
        # every live extent; a fresh async sweep is byte-identical
        store.maintain()
        for n, w in want.items():
            h = store.submit_read_raw(eng, n)
            got = h.wait(10.0)
            for k, v in w.items():
                assert np.array_equal(np.asarray(got[k]), v), (n, k)
            h.release()
    finally:
        eng.close()
        store.close()


# ---------------------------------------------------------------------------
# readahead coverage stats (satellite: silent-no-op fix)
# ---------------------------------------------------------------------------
def test_store_readahead_reports_coverage(tmp_path):
    store, want = _store_with_layers(tmp_path, "super")
    try:
        store.readahead(list(want))
        st = store.readahead_stats
        assert st is not None
        assert st["layers_requested"] == len(want)
        if st["madvise_available"]:
            assert st["layers_hinted"] == len(want)
            assert st["bytes_hinted"] > 0
        else:  # the old silent no-op now reports itself
            assert st["layers_hinted"] == 0
    finally:
        store.close()


def test_run_result_carries_readahead_stats(tmp_path):
    from repro.core.engine import ColdEngine
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    eng = ColdEngine(layers, tmp_path / "s", store_fmt="super",
                     shader_cache=False)
    eng.decide(x, n_little=2)
    res = eng.run_cold(x, n_little=2)
    assert res.readahead is not None and res.readahead["mode"] == "engine"
    assert res.readahead["layers_hinted"] >= 1
    assert res.readahead["bytes_hinted"] > 0
    seq = eng.run_cold(x, mode="sequential")
    assert seq.readahead is not None and seq.readahead["mode"] == "madvise"


# ---------------------------------------------------------------------------
# stage engine
# ---------------------------------------------------------------------------
def test_stage_engine_host_matches_stage_weights():
    from repro.core.staging import stage_weights

    w = {"a": np.arange(16, dtype=np.float32).reshape(4, 4)}
    se = StageEngine(backend="host")
    got = se.stage(w)
    ref = stage_weights(w)
    assert np.array_equal(np.asarray(got["a"]), np.asarray(ref["a"]))
    assert se.stats["staged"] == 1
    se.close()


def test_stage_engine_stages_readonly_views():
    se = StageEngine(backend="host")
    a = np.arange(16, dtype=np.float32)
    a.flags.writeable = False   # what ReadTicket.wait hands back
    got = se.stage({"a": a})
    assert np.array_equal(np.asarray(got["a"]),
                          np.arange(16, dtype=np.float32))
    se.close()


# ---------------------------------------------------------------------------
# ProfileDB approximate shape-class matching (satellite)
# ---------------------------------------------------------------------------
def test_profile_db_approx_exact_first_then_sibling(tmp_path):
    from repro.core.profiler import OpProfile, ProfileDB
    from repro.core.registry import (
        LayerSpec, shape_class_key, shape_class_sibling_key,
    )

    spec = LayerSpec("l", "linear", {"in_features": 8, "out_features": 8},
                     {"w": (8, 8)})
    k1 = shape_class_key(spec, input_shape=(1, 8), input_dtype="float32")
    k4 = shape_class_key(spec, input_shape=(4, 8), input_dtype="float32")
    sib1 = shape_class_sibling_key(spec, input_shape=(1, 8),
                                   input_dtype="float32")
    sib4 = shape_class_sibling_key(spec, input_shape=(4, 8),
                                   input_dtype="float32")
    assert k1 != k4 and sib1 == sib4   # siblings: same up to batch dim

    db = ProfileDB(tmp_path / "db.json")
    p = OpProfile(layer="l", kernel="direct", read_raw_s=1.0,
                  transform_s=0.1, read_cached_s=0.5, exec_s=0.01,
                  compile_s=0.0, raw_bytes=256, transformed_bytes=256)
    db.put(k1, "direct", p, sibling_key=sib1)
    # exact miss without approx
    assert db.get(k4, "direct", sibling_key=sib4) is None
    # approx fans the batch-1 profile out to batch 4
    got = db.get(k4, "direct", sibling_key=sib4, approx=True)
    assert got is not None and got.read_raw_s == 1.0
    assert db.stats["approx_hits"] == 1
    # exact entries always win over siblings
    p2 = OpProfile(layer="l", kernel="direct", read_raw_s=9.0,
                   transform_s=0.1, read_cached_s=0.5, exec_s=0.01,
                   compile_s=0.0, raw_bytes=256, transformed_bytes=256)
    db.put(k4, "direct", p2, sibling_key=sib4)
    assert db.get(k4, "direct", sibling_key=sib4,
                  approx=True).read_raw_s == 9.0
    # sibling index survives a save/load cycle
    db.save()
    db2 = ProfileDB(tmp_path / "db.json")
    assert db2.get(shape_class_key(
        spec, input_shape=(16, 8), input_dtype="float32"), "direct",
        sibling_key=sib1, approx=True) is not None


def test_batch_dim_changes_but_feature_dims_do_not_sibling():
    from repro.core.registry import LayerSpec, shape_class_sibling_key

    spec = LayerSpec("l", "linear", {"in_features": 8, "out_features": 8},
                     {"w": (8, 8)})
    a = shape_class_sibling_key(spec, input_shape=(1, 8),
                                input_dtype="float32")
    b = shape_class_sibling_key(spec, input_shape=(1, 16),
                                input_dtype="float32")
    assert a != b   # non-batch dims still separate classes
    assert shape_class_sibling_key(
        LayerSpec("r", "stateless"), input_shape=(1, 8),
        input_dtype="float32") is None


# ---------------------------------------------------------------------------
# ColdServer: byte-budget admission + idle-tick compaction
# ---------------------------------------------------------------------------
def test_server_byte_budget_and_idle_compaction(tmp_path):
    from repro.executor.server import ColdServer
    from repro.models.cnn import build_cnn

    srv = ColdServer(tmp_path / "srv", max_concurrent_preps=2,
                     max_read_bytes_in_flight=8 << 20,
                     idle_compaction_min_interval_s=0.0)
    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    srv.add_model("m0", layers, store_fmt="super", shader_cache=False)
    srv.decide("m0", x)
    y0 = np.asarray(srv.cold_start("m0", x).result().output)
    assert srv.io_engine.max_bytes_in_flight == 8 << 20
    # leave dead extents, then let the engine's idle edge compact them
    st = srv.engines["m0"].store
    st.write_cached("scratch_l", "k", {"w": np.ones((64, 64), np.float32)})
    st._super(flush_all=True)
    st.drop_cached("scratch_l", "k")
    st._super(flush_all=True)
    assert st._super().reclaimable_bytes() > 0
    y1 = np.asarray(srv.cold_start("m0", x).result().output)
    deadline = time.monotonic() + 10.0
    while (time.monotonic() < deadline
           and srv.stats["idle_compactions"] == 0):
        time.sleep(0.05)
    assert srv.stats["idle_compactions"] >= 1
    assert srv.stats["idle_compaction_bytes"] > 0
    np.testing.assert_array_equal(y0, y1)
    # a post-compaction cold start still reads the compacted container
    y2 = np.asarray(srv.cold_start("m0", x).result().output)
    np.testing.assert_array_equal(y0, y2)
    h = srv.health()
    assert h["io_engine"]["in_flight"] == 0
