"""Docs CI: validate intra-repo markdown links and run the README quickstart.

Two checks, both hard failures:

  1. every relative link target in README.md and docs/*.md exists on disk
     (external http(s)/mailto links and pure #anchors are skipped);
  2. the first ```python block in README.md (the quickstart) executes
     cleanly in a subprocess with PYTHONPATH=src.

Run: python tools/check_docs.py  (from the repo root or anywhere)
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excluding images is unnecessary (targets must exist either
# way); inline code spans are stripped first so `foo[0](x)` can't false-match
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_SPAN_RE = re.compile(r"`[^`]*`")
_FENCE_RE = re.compile(r"```.*?```", re.S)


def check_links() -> list[str]:
    errors = []
    files = [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))
    for md in files:
        text = _FENCE_RE.sub("", md.read_text())
        text = _CODE_SPAN_RE.sub("", text)
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:  # pure in-page anchor
                continue
            resolved = (md.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def extract_quickstart() -> str:
    readme = (REPO / "README.md").read_text()
    m = re.search(r"```python\n(.*?)```", readme, re.S)
    if not m:
        raise SystemExit("README.md has no ```python quickstart block")
    return m.group(1)


def run_quickstart() -> int:
    code = extract_quickstart()
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    print("-- running README quickstart --")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          cwd=REPO, timeout=900)
    return proc.returncode


def main() -> int:
    errors = check_links()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if errors:
        return 1
    print(f"links ok ({len(list((REPO / 'docs').glob('*.md')))} docs files "
          "+ README)")
    rc = run_quickstart()
    if rc != 0:
        print("ERROR: README quickstart failed", file=sys.stderr)
        return rc
    print("quickstart ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
