#!/usr/bin/env python
"""Fleet-wide store scrub — walk a serving root, verify every super-bundle
container end to end, and compact the slack out.

Per container the scrub:

  1. opens it (which replays any pending intent-journal transaction — the
     same crash recovery every reader performs);
  2. eager-verifies EVERY extent against its recorded CRC-32C — including
     the cache entries a lazy-verify reader would only audit on use.
     A corrupt cache entry is dropped (it is recomputable from raw);
     corrupt raw marks the container bad (raw is the source of truth —
     only a fresh model install can repair it);
  3. compacts when there is anything to reclaim: dead extents from
     dropped/superseded entries, plus the drops step 2 just made.

The report is machine-readable (``--json``) so a cron job can alert on
``ok: false``. ``--smoke`` runs a hermetic self-test (CI gate): builds a
store, injects bit-rot, and asserts the scrub finds, repairs, and reports
it.

Usage:
    PYTHONPATH=src python tools/scrub.py <root> [--json] [--no-compact]
    PYTHONPATH=src python tools/scrub.py --smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

try:
    from repro.checkpoint.superbundle import (
        IntegrityError, SuperBundle, compact,
    )
except ImportError:  # invoked as `python tools/scrub.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    from repro.checkpoint.superbundle import (
        IntegrityError, SuperBundle, compact,
    )


def scrub_bundle(path: Path, *, do_compact: bool = True) -> dict:
    """Scrub one container. ``ok`` means the container is healthy after the
    scrub — dropped cache entries are repairs, not failures; corrupt raw
    (or an unreadable file) is a failure."""
    rec = {"path": str(path), "ok": True, "raw_ok": True,
           "recovered_txn_drops": 0, "dropped": [], "compacted": False,
           "reclaimed_bytes": 0, "errors": []}
    try:
        with SuperBundle(path, verify="lazy") as sb:  # open replays journal
            rec["recovered_txn_drops"] = len(sb.dropped)
            try:
                sb._verify_all()  # the eager audit, on demand
            except IntegrityError as e:
                rec["ok"] = rec["raw_ok"] = False
                rec["errors"].append(str(e))
            rec["dropped"] = list(sb.dropped)
            slack = sb.reclaimable_bytes()
    except Exception as e:
        rec["ok"] = False
        rec["errors"].append(f"unreadable: {e!r}")
        return rec
    # the audit's drops live only in the closed reader's memory; compaction
    # persists them and reclaims their extents (plus any pre-existing slack)
    if do_compact and rec["raw_ok"] and (slack > 0 or rec["dropped"]):
        try:
            res = compact(path)
            rec["compacted"] = True
            rec["reclaimed_bytes"] = res["reclaimed_bytes"]
            for d in res["dropped"]:
                if d not in rec["dropped"]:
                    rec["dropped"].append(d)
        except Exception as e:
            rec["ok"] = False
            rec["errors"].append(f"compact failed: {e!r}")
    return rec


def scrub_store(root: Path, *, do_compact: bool = True) -> dict:
    """Scrub every ``*.superbundle`` under ``root``; aggregate report."""
    root = Path(root)
    t0 = time.perf_counter()
    reports = [scrub_bundle(p, do_compact=do_compact)
               for p in sorted(root.glob("**/*.superbundle"))]
    return {
        "root": str(root),
        "files": len(reports),
        "ok": all(r["ok"] for r in reports),
        "bad_files": [r["path"] for r in reports if not r["ok"]],
        "dropped": sum(len(r["dropped"]) for r in reports),
        "reclaimed_bytes": sum(r["reclaimed_bytes"] for r in reports),
        "elapsed_s": time.perf_counter() - t0,
        "reports": reports,
    }


# ---------------------------------------------------------------------------
# --smoke: hermetic self-test (CI gate)
# ---------------------------------------------------------------------------
def _gate(ok: bool, msg: str, failures: list):
    print(("PASS " if ok else "FAIL ") + msg)
    if not ok:
        failures.append(msg)


def _flip_byte(path: Path, offset: int):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def smoke() -> int:
    import tempfile

    import numpy as np

    from repro.checkpoint import LayerStore
    from repro.checkpoint.superbundle import read_super_header

    failures: list = []
    with tempfile.TemporaryDirectory(prefix="nnv12_scrub_") as td:
        root = Path(td)
        rng = np.random.default_rng(0)
        for model in ("m1", "m2"):
            store = LayerStore(root / model, fmt="super")
            for i in range(3):
                w = {"w": rng.standard_normal((64, 64)).astype(np.float32)}
                store.write_raw(f"layer{i}", w)
                store.write_cached(f"layer{i}", "kern", {"wT": w["w"].T})
            # drop one entry -> dead extent (slack) left for the scrub
            store.drop_cached("layer2", "kern")
            store._super(flush_all=True)
            store.close()

        # bit-rot a CACHED extent in m1 (repairable: drop + compact) ...
        p1 = root / "m1" / "model.superbundle"
        hdr = read_super_header(p1)
        ent = hdr["layers"]["layer0"]["cache"]["kern"][0]
        _flip_byte(p1, ent["offset"] + ent["nbytes"] // 2)
        # ... and a RAW extent in m2 (unrepairable: container marked bad)
        p2 = root / "m2" / "model.superbundle"
        hdr2 = read_super_header(p2)
        ent2 = hdr2["layers"]["layer1"]["raw"][0]
        _flip_byte(p2, ent2["offset"] + ent2["nbytes"] // 2)

        rep = scrub_store(root)
        by_path = {r["path"]: r for r in rep["reports"]}
        r1, r2 = by_path[str(p1)], by_path[str(p2)]

        _gate(rep["files"] == 2, f"scrub walked both containers "
              f"(files={rep['files']})", failures)
        _gate(r1["ok"] and r1["raw_ok"],
              "cache bit-rot container still ok after repair", failures)
        _gate(any(d.get("layer") == "layer0" for d in r1["dropped"]),
              f"corrupt cache entry detected+dropped ({r1['dropped']})",
              failures)
        _gate(r1["compacted"] and r1["reclaimed_bytes"] > 0,
              f"slack compacted ({r1['reclaimed_bytes']}B reclaimed)",
              failures)
        _gate(not r2["ok"] and not r2["raw_ok"],
              "raw bit-rot marks the container bad", failures)
        _gate(not rep["ok"] and str(p2) in rep["bad_files"],
              "aggregate report surfaces the bad container", failures)

        # post-repair: m1 must verify clean with nothing left to reclaim
        rep2 = scrub_bundle(p1)
        _gate(rep2["ok"] and not rep2["dropped"]
              and rep2["reclaimed_bytes"] == 0,
              "second scrub of the repaired container is clean", failures)

        # the dropped entry is recomputable: the store serves raw fine and
        # read_cached returns {} (the runtime ladder recomputes from raw)
        store = LayerStore(root / "m1", fmt="super")
        _gate(store.read_raw("layer0", mmap=False)["w"].shape == (64, 64),
              "raw still served after cache repair", failures)
        _gate(store.read_cached("layer0", "kern") == {},
              "dropped cache entry reads as absent, not garbage", failures)
        store.close()

    if failures:
        print(f"\n--smoke: {len(failures)} gate(s) FAILED")
        return 1
    print("\n--smoke: all gates passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("root", nargs="?", help="serving/store root to walk")
    ap.add_argument("--json", action="store_true",
                    help="print the full machine-readable report")
    ap.add_argument("--no-compact", action="store_true",
                    help="verify only; do not rewrite containers")
    ap.add_argument("--smoke", action="store_true",
                    help="run the hermetic self-test and exit")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    if not args.root:
        ap.error("a store root is required (or --smoke)")
    rep = scrub_store(Path(args.root), do_compact=not args.no_compact)
    if args.json:
        print(json.dumps(rep, indent=1))
    else:
        for r in rep["reports"]:
            status = "ok" if r["ok"] else "BAD"
            print(f"{status:3s} {r['path']}  dropped={len(r['dropped'])} "
                  f"reclaimed={r['reclaimed_bytes']}B "
                  f"errors={len(r['errors'])}")
        print(f"{rep['files']} container(s), ok={rep['ok']}, "
              f"dropped={rep['dropped']}, "
              f"reclaimed={rep['reclaimed_bytes']}B "
              f"in {rep['elapsed_s']:.2f}s")
    return 0 if rep["ok"] else 2


if __name__ == "__main__":
    sys.exit(main())
